//! Mini property-testing harness.
//!
//! `proptest` is not reachable offline (DESIGN.md §3), so this module
//! provides the slice of it the test suite needs: run a property over many
//! seeded random cases and report the failing seed so a failure is
//! reproducible with `PROP_SEED=<seed> cargo test <name>`.

pub mod golden;
pub mod oracle;

use crate::rng::Xoshiro256;

/// Number of cases per property (override with env `PROP_CASES`).
pub fn default_cases() -> usize {
    std::env::var("PROP_CASES")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(64)
}

/// Run `property` over `cases` RNG-seeded inputs. The closure receives a
/// fresh RNG per case and must panic on violation; the harness wraps the
/// panic with the case seed.
pub fn check(name: &str, cases: usize, property: impl Fn(&mut Xoshiro256)) {
    let base: u64 = std::env::var("PROP_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0xA2C1D2);
    for case in 0..cases {
        let seed = base.wrapping_add(case as u64).wrapping_mul(0x9E3779B97F4A7C15);
        let mut rng = Xoshiro256::seed_from_u64(seed);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            property(&mut rng)
        }));
        if let Err(payload) = result {
            let msg = payload
                .downcast_ref::<String>()
                .cloned()
                .or_else(|| payload.downcast_ref::<&str>().map(|s| s.to_string()))
                .unwrap_or_else(|| "<non-string panic>".into());
            panic!(
                "property '{name}' failed on case {case} (seed {seed:#x}): {msg}\n\
                 reproduce with PROP_SEED={base} (case offset {case})"
            );
        }
    }
}

/// Uniform float in `[lo, hi)`.
pub fn f64_in(rng: &mut Xoshiro256, lo: f64, hi: f64) -> f64 {
    lo + (hi - lo) * rng.next_f64()
}

/// Uniform usize in `[lo, hi)`.
pub fn usize_in(rng: &mut Xoshiro256, lo: usize, hi: usize) -> usize {
    lo + rng.gen_range(hi - lo)
}

/// A random f32 vector with entries in `[-scale, scale]`.
pub fn vec_f32(rng: &mut Xoshiro256, len: usize, scale: f32) -> Vec<f32> {
    (0..len)
        .map(|_| (rng.next_f32() * 2.0 - 1.0) * scale)
        .collect()
}

/// Minimal strict JSON validator (no parser crate offline): checks that
/// `text` is exactly one well-formed JSON value, reporting the byte
/// offset of the first violation. Used to pin the hand-rolled
/// `metrics::render_records` writer (escaping, NaN→null) and the
/// `BENCH_*.json` artifact schemas without a serde round-trip.
pub fn validate_json(text: &str) -> Result<(), String> {
    let b = text.as_bytes();
    let mut pos = 0usize;
    skip_ws(b, &mut pos);
    value(b, &mut pos)?;
    skip_ws(b, &mut pos);
    if pos != b.len() {
        return Err(format!("trailing content at byte {pos}"));
    }
    Ok(())
}

fn skip_ws(b: &[u8], pos: &mut usize) {
    while *pos < b.len() && matches!(b[*pos], b' ' | b'\t' | b'\n' | b'\r') {
        *pos += 1;
    }
}

fn value(b: &[u8], pos: &mut usize) -> Result<(), String> {
    let err = |pos: usize, what: &str| Err(format!("{what} at byte {pos}"));
    match b.get(*pos).copied() {
        None => err(*pos, "unexpected end of input"),
        Some(b'{') => {
            *pos += 1;
            skip_ws(b, pos);
            if b.get(*pos) == Some(&b'}') {
                *pos += 1;
                return Ok(());
            }
            loop {
                skip_ws(b, pos);
                string(b, pos)?;
                skip_ws(b, pos);
                if b.get(*pos) != Some(&b':') {
                    return err(*pos, "expected ':'");
                }
                *pos += 1;
                skip_ws(b, pos);
                value(b, pos)?;
                skip_ws(b, pos);
                match b.get(*pos).copied() {
                    Some(b',') => *pos += 1,
                    Some(b'}') => {
                        *pos += 1;
                        return Ok(());
                    }
                    _ => return err(*pos, "expected ',' or '}'"),
                }
            }
        }
        Some(b'[') => {
            *pos += 1;
            skip_ws(b, pos);
            if b.get(*pos) == Some(&b']') {
                *pos += 1;
                return Ok(());
            }
            loop {
                skip_ws(b, pos);
                value(b, pos)?;
                skip_ws(b, pos);
                match b.get(*pos).copied() {
                    Some(b',') => *pos += 1,
                    Some(b']') => {
                        *pos += 1;
                        return Ok(());
                    }
                    _ => return err(*pos, "expected ',' or ']'"),
                }
            }
        }
        Some(b'"') => string(b, pos),
        Some(b't') => literal(b, pos, "true"),
        Some(b'f') => literal(b, pos, "false"),
        Some(b'n') => literal(b, pos, "null"),
        Some(c) if c == b'-' || c.is_ascii_digit() => number(b, pos),
        Some(c) => err(*pos, &format!("unexpected byte {c:?}")),
    }
}

fn literal(b: &[u8], pos: &mut usize, lit: &str) -> Result<(), String> {
    if b[*pos..].starts_with(lit.as_bytes()) {
        *pos += lit.len();
        Ok(())
    } else {
        Err(format!("expected '{lit}' at byte {pos}"))
    }
}

fn string(b: &[u8], pos: &mut usize) -> Result<(), String> {
    if b.get(*pos) != Some(&b'"') {
        return Err(format!("expected '\"' at byte {pos}"));
    }
    *pos += 1;
    while let Some(&c) = b.get(*pos) {
        match c {
            b'"' => {
                *pos += 1;
                return Ok(());
            }
            b'\\' => {
                *pos += 1;
                match b.get(*pos).copied() {
                    Some(b'"' | b'\\' | b'/' | b'b' | b'f' | b'n' | b'r' | b't') => *pos += 1,
                    Some(b'u') => {
                        *pos += 1;
                        for _ in 0..4 {
                            if !b.get(*pos).is_some_and(u8::is_ascii_hexdigit) {
                                return Err(format!("bad \\u escape at byte {pos}"));
                            }
                            *pos += 1;
                        }
                    }
                    _ => return Err(format!("bad escape at byte {pos}")),
                }
            }
            0x00..=0x1F => return Err(format!("unescaped control byte at {pos}")),
            _ => *pos += 1, // UTF-8 continuation bytes pass through
        }
    }
    Err("unterminated string".to_string())
}

fn number(b: &[u8], pos: &mut usize) -> Result<(), String> {
    let start = *pos;
    if b.get(*pos) == Some(&b'-') {
        *pos += 1;
    }
    let digits = |b: &[u8], pos: &mut usize| -> bool {
        let d0 = *pos;
        while b.get(*pos).is_some_and(u8::is_ascii_digit) {
            *pos += 1;
        }
        *pos > d0
    };
    if !digits(b, pos) {
        return Err(format!("bad number at byte {start}"));
    }
    if b.get(*pos) == Some(&b'.') {
        *pos += 1;
        if !digits(b, pos) {
            return Err(format!("bad fraction at byte {start}"));
        }
    }
    if matches!(b.get(*pos).copied(), Some(b'e' | b'E')) {
        *pos += 1;
        if matches!(b.get(*pos).copied(), Some(b'+' | b'-')) {
            *pos += 1;
        }
        if !digits(b, pos) {
            return Err(format!("bad exponent at byte {start}"));
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let count = std::cell::Cell::new(0);
        check("trivial", 10, |_| {
            count.set(count.get() + 1);
        });
        assert_eq!(count.get(), 10);
    }

    #[test]
    fn failing_property_reports_seed() {
        let result = std::panic::catch_unwind(|| {
            check("always-fails", 3, |_| panic!("boom"));
        });
        let msg = match result {
            Err(p) => p.downcast_ref::<String>().unwrap().clone(),
            Ok(_) => panic!("should have failed"),
        };
        assert!(msg.contains("always-fails"));
        assert!(msg.contains("seed"));
        assert!(msg.contains("boom"));
    }

    #[test]
    fn generators_in_bounds() {
        let mut rng = Xoshiro256::seed_from_u64(1);
        for _ in 0..100 {
            let f = f64_in(&mut rng, -2.0, 3.0);
            assert!((-2.0..3.0).contains(&f));
            let u = usize_in(&mut rng, 5, 10);
            assert!((5..10).contains(&u));
        }
        let v = vec_f32(&mut rng, 32, 2.0);
        assert_eq!(v.len(), 32);
        assert!(v.iter().all(|&x| (-2.0..=2.0).contains(&x)));
    }

    #[test]
    fn json_validator_accepts_well_formed() {
        for ok in [
            "null",
            " true ",
            "-1.5e-3",
            "\"a\\n\\\"b\\u00e9\"",
            "[]",
            "[1, [2, {\"k\": null}], \"s\"]",
            "{\"a\": 1, \"b\": [true, false]}",
            "{\"unicode: é🦀\": \"ok\"}",
        ] {
            validate_json(ok).unwrap_or_else(|e| panic!("{ok}: {e}"));
        }
    }

    #[test]
    fn json_validator_rejects_malformed() {
        for bad in [
            "",
            "nul",
            "{",
            "[1,]",
            "{\"a\" 1}",
            "{\"a\": 1,}",
            "\"unterminated",
            "\"bad \\x escape\"",
            "\"ctrl \u{0}\"",
            "1.2.3",
            "1 2",
            "NaN",
            "{'single': 1}",
        ] {
            assert!(validate_json(bad).is_err(), "should reject: {bad:?}");
        }
    }
}
