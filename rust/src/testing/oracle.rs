//! The paper-conformance oracle: checked-in reference values with
//! tolerances for every registry experiment, and the `a2cid2 verify`
//! machinery that diffs a run's [`Record`]s against them.
//!
//! The oracle itself is data, not code: `rust/oracle/paper.toml` holds
//! one section per `(experiment id, metric)` pair with the expected
//! value, an absolute/relative tolerance band, and a scale-applicability
//! flag (`any` / `quick` / `full`). Spectra-driven experiments carry
//! tight bands straight from the paper's Fig. 6 closed forms; training
//! experiments carry the quantitative form of the claims their module
//! tests already pin (so `verify all` at quick scale is a superset of
//! the unit-test contract, now enforced end-to-end over the same
//! consolidated rows that `BENCH_experiments.json` archives).
//!
//! A metric names a field of the consolidated per-experiment record
//! (`final_loss`, `final_consensus`, `accuracy`, `n_rows`, `wall_ms`),
//! a dotted path into its nested row set (`rows.2.chi1` — index, then
//! field), or a cross-metric RATIO `"<path> / <path>"` — both sides
//! resolve through [`extract`] and the observed value is their quotient,
//! so claims like "A²CiD²'s comms-to-target is at most half of
//! AD-PSGD's" are one checked-in row. A check passes iff the observed
//! value is finite and `|observed − expected| ≤ abs + rel·|expected|`;
//! no tolerance keys means an exact match. Verdicts render to `BENCH_conformance.json`
//! (one row per compared metric) via the same serde-free [`Record`]
//! writer as every other artifact.

use std::path::Path;
use std::sync::OnceLock;

use crate::experiments::common::Scale;
use crate::experiments::registry;
use crate::metrics::{render_records, Record, Value};
use crate::runtime::artifacts::write_atomic;

/// Which scales a check applies to; out-of-scale checks report
/// [`Outcome::Skip`] instead of running.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum AppliesTo {
    Any,
    QuickOnly,
    FullOnly,
}

impl AppliesTo {
    fn parse(s: &str) -> crate::Result<AppliesTo> {
        match s {
            "any" => Ok(AppliesTo::Any),
            "quick" => Ok(AppliesTo::QuickOnly),
            "full" => Ok(AppliesTo::FullOnly),
            other => anyhow::bail!("scales must be any|quick|full, got '{other}'"),
        }
    }

    pub fn includes(self, scale: Scale) -> bool {
        match self {
            AppliesTo::Any => true,
            AppliesTo::QuickOnly => scale == Scale::Quick,
            AppliesTo::FullOnly => scale == Scale::Full,
        }
    }
}

/// One reference row: experiment id + metric path → expected value with
/// a tolerance band.
#[derive(Clone, Debug)]
pub struct Check {
    pub id: String,
    /// Dotted path into the consolidated experiment record
    /// (`final_loss`, `n_rows`, `rows.<idx>.<field>`, …).
    pub metric: String,
    pub expected: f64,
    /// Absolute tolerance (0 = none).
    pub abs: f64,
    /// Relative tolerance, scaled by `|expected|` (0 = none).
    pub rel: f64,
    pub scales: AppliesTo,
    /// Where the reference value comes from (paper table/figure, or the
    /// module-test invariant it quantifies).
    pub note: String,
}

/// How one check fared against one run.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Outcome {
    Pass,
    Fail,
    Skip,
}

impl Outcome {
    pub fn as_str(self) -> &'static str {
        match self {
            Outcome::Pass => "pass",
            Outcome::Fail => "fail",
            Outcome::Skip => "skip",
        }
    }
}

/// A judged check: the `BENCH_conformance.json` row.
#[derive(Clone, Debug)]
pub struct Verdict {
    pub check: Check,
    pub outcome: Outcome,
    /// The value extracted from the run (`None`: metric missing/null, or
    /// the check was skipped without running).
    pub observed: Option<f64>,
}

impl Check {
    /// The half-width of the acceptance band around `expected`.
    pub fn allowed(&self) -> f64 {
        self.abs + self.rel * self.expected.abs()
    }

    /// Judge this check against a consolidated experiment record.
    pub fn judge(&self, rec: &Record) -> Verdict {
        let observed = extract_metric(rec, &self.metric);
        let pass = matches!(observed, Some(o)
            if o.is_finite() && (o - self.expected).abs() <= self.allowed());
        Verdict {
            check: self.clone(),
            outcome: if pass { Outcome::Pass } else { Outcome::Fail },
            observed,
        }
    }

    /// A skip verdict (check not applicable at the running scale).
    pub fn skip(&self) -> Verdict {
        Verdict { check: self.clone(), outcome: Outcome::Skip, observed: None }
    }
}

impl Verdict {
    /// `|observed − expected| − allowed`: negative inside the band.
    pub fn margin(&self) -> Option<f64> {
        self.observed.map(|o| (o - self.check.expected).abs() - self.check.allowed())
    }

    /// One line with everything a failure report needs: observed vs
    /// expected and the tolerance that was applied. (The outcome itself
    /// is not embedded — callers prefix it, as `verify_cli` does.)
    pub fn message(&self) -> String {
        let c = &self.check;
        let obs = match self.observed {
            Some(o) => format!("observed {o}"),
            None => "metric missing (no such field, or null)".to_string(),
        };
        format!(
            "{}/{}: {}, expected {} ± {} (abs {} + rel {}·|expected|){}",
            c.id,
            c.metric,
            obs,
            c.expected,
            c.allowed(),
            c.abs,
            c.rel,
            if c.note.is_empty() { String::new() } else { format!(" — {}", c.note) },
        )
    }

    /// The `BENCH_conformance.json` row for this verdict.
    pub fn record(&self) -> Record {
        let c = &self.check;
        Record::new()
            .str("id", c.id.clone())
            .str("metric", c.metric.clone())
            .str("outcome", self.outcome.as_str())
            .opt_f64("observed", self.observed)
            .f64("expected", c.expected)
            .f64("abs", c.abs)
            .f64("rel", c.rel)
            .f64("allowed", c.allowed())
            .opt_f64("margin", self.margin())
            .str(
                "scales",
                match c.scales {
                    AppliesTo::Any => "any",
                    AppliesTo::QuickOnly => "quick",
                    AppliesTo::FullOnly => "full",
                },
            )
            .str("note", c.note.clone())
    }
}

/// Walk a dotted metric path through a record: a name segment selects a
/// field, and a numeric segment indexes into a nested
/// [`Value::Records`] array (so `rows.2.chi1` is row 2's `chi1`).
/// Resolves to `None` unless every intermediate segment exists and the
/// leaf is numeric.
pub fn extract(rec: &Record, path: &str) -> Option<f64> {
    enum Cursor<'a> {
        Rec(&'a Record),
        Val(&'a Value),
    }
    let mut cur = Cursor::Rec(rec);
    for seg in path.split('.') {
        cur = match cur {
            Cursor::Rec(r) => Cursor::Val(r.get(seg)?),
            Cursor::Val(Value::Records(rows)) => {
                Cursor::Rec(rows.get(seg.parse::<usize>().ok()?)?)
            }
            Cursor::Val(_) => return None, // cannot path into a scalar
        };
    }
    match cur {
        Cursor::Val(v) => v.as_f64(),
        Cursor::Rec(_) => None, // path ended on a row, not a metric
    }
}

/// [`extract`] extended with the ratio form: a metric containing
/// `" / "` resolves both paths and observes their quotient (a zero
/// denominator yields a non-finite value, which every check rejects).
pub fn extract_metric(rec: &Record, metric: &str) -> Option<f64> {
    match metric.split_once(" / ") {
        Some((num, den)) => Some(extract(rec, num.trim())? / extract(rec, den.trim())?),
        None => extract(rec, metric),
    }
}

/// The checked-in oracle: every reference row, in file order.
#[derive(Clone, Debug, Default)]
pub struct Oracle {
    pub checks: Vec<Check>,
}

impl Oracle {
    /// Parse the `paper.toml` subset: `[<id>.<metric.path>]` sections
    /// with `key = value` lines (`expected`, `abs`, `rel`, `scales`,
    /// `note`), `#` comments, blank lines.
    pub fn parse(text: &str) -> crate::Result<Oracle> {
        let mut checks: Vec<Check> = Vec::new();
        let mut open: Option<(Check, bool)> = None; // (check, saw_expected)
        let close = |open: &mut Option<(Check, bool)>,
                     checks: &mut Vec<Check>|
         -> crate::Result<()> {
            if let Some((check, saw_expected)) = open.take() {
                anyhow::ensure!(
                    saw_expected,
                    "oracle section [{}.{}] has no `expected =` line",
                    check.id,
                    check.metric
                );
                checks.push(check);
            }
            Ok(())
        };
        for (lineno, raw) in text.lines().enumerate() {
            let line = raw.trim();
            let err = |msg: String| anyhow::anyhow!("oracle line {}: {msg}", lineno + 1);
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            if let Some(header) = line.strip_prefix('[').and_then(|l| l.strip_suffix(']')) {
                close(&mut open, &mut checks)?;
                let (id, metric) = header
                    .split_once('.')
                    .ok_or_else(|| err(format!("section '[{header}]' needs <id>.<metric>")))?;
                anyhow::ensure!(
                    !id.is_empty() && !metric.is_empty(),
                    err(format!("empty id or metric in '[{header}]'"))
                );
                open = Some((
                    Check {
                        id: id.to_string(),
                        metric: metric.to_string(),
                        expected: 0.0,
                        abs: 0.0,
                        rel: 0.0,
                        scales: AppliesTo::Any,
                        note: String::new(),
                    },
                    false,
                ));
                continue;
            }
            let (key, value) = line
                .split_once('=')
                .ok_or_else(|| err(format!("expected `key = value`, got '{line}'")))?;
            let (key, value) = (key.trim(), value.trim());
            let (check, saw_expected) = open
                .as_mut()
                .ok_or_else(|| err(format!("'{key}' outside any [id.metric] section")))?;
            let unquote = |v: &str| -> Option<String> {
                v.strip_prefix('"')?.strip_suffix('"').map(str::to_string)
            };
            let num = |v: &str| -> crate::Result<f64> {
                v.parse::<f64>().map_err(|e| err(format!("{key} = {v}: {e}")))
            };
            match key {
                "expected" => {
                    check.expected = num(value)?;
                    *saw_expected = true;
                }
                "abs" => {
                    check.abs = num(value)?;
                    anyhow::ensure!(check.abs >= 0.0, err("abs must be >= 0".into()));
                }
                "rel" => {
                    check.rel = num(value)?;
                    anyhow::ensure!(check.rel >= 0.0, err("rel must be >= 0".into()));
                }
                "scales" => {
                    let v = unquote(value)
                        .ok_or_else(|| err(format!("scales must be quoted: {value}")))?;
                    check.scales = AppliesTo::parse(&v).map_err(|e| err(e.to_string()))?;
                }
                "note" => {
                    check.note = unquote(value)
                        .ok_or_else(|| err(format!("note must be quoted: {value}")))?;
                }
                other => anyhow::bail!(err(format!("unknown key '{other}'"))),
            }
        }
        close(&mut open, &mut checks)?;
        anyhow::ensure!(!checks.is_empty(), "oracle file declares no checks");
        Ok(Oracle { checks })
    }

    /// The checked-in oracle (`rust/oracle/paper.toml`), parsed once per
    /// process. A malformed checked-in file is a programmer error and
    /// panics (`builtin_oracle_parses` pins it in CI).
    pub fn builtin() -> &'static Oracle {
        static ORACLE: OnceLock<Oracle> = OnceLock::new();
        ORACLE.get_or_init(|| {
            Oracle::parse(include_str!("../../oracle/paper.toml"))
                .expect("rust/oracle/paper.toml must parse")
        })
    }

    /// All checks for one experiment id, in file order.
    pub fn checks_for(&self, id: &str) -> Vec<&Check> {
        self.checks.iter().filter(|c| c.id == id).collect()
    }

    /// Judge every check of `id` against one consolidated experiment
    /// record at `scale` (out-of-scale checks come back as skips).
    pub fn judge(&self, id: &str, rec: &Record, scale: Scale) -> Vec<Verdict> {
        self.checks_for(id)
            .into_iter()
            .map(|c| if c.scales.includes(scale) { c.judge(rec) } else { c.skip() })
            .collect()
    }
}

/// The `a2cid2 verify <id|all>` body: resolve experiments through the
/// registry, run each one that has in-scale oracle entries, diff the
/// consolidated record row-by-row, write `BENCH_conformance.json` (and,
/// with `experiments_json`, the consolidated per-experiment artifact —
/// so one registry pass yields both, instead of CI running `experiment
/// all` and `verify all` back to back), and fail only AFTER the
/// artifacts are flushed — a red run still archives its evidence, and a
/// mid-run experiment error still flushes the verdicts collected so far
/// (the same discipline as `registry::run_cli`).
pub fn verify_cli(
    id: &str,
    filter: Option<&str>,
    json: Option<&Path>,
    experiments_json: Option<&Path>,
    scale: Scale,
) -> crate::Result<()> {
    let oracle = Oracle::builtin();
    let selected = registry::select(id, filter)?;
    let mut rows = Vec::new();
    let mut exp_rows = Vec::new();
    let (mut n_pass, mut n_fail, mut n_skip) = (0usize, 0usize, 0usize);
    let mut failures: Vec<String> = Vec::new();
    let mut run_outcome = Ok(());
    for exp in selected {
        let checks = oracle.checks_for(exp.id());
        if checks.is_empty() {
            println!("=== verify {} === no oracle entries", exp.id());
            continue;
        }
        let verdicts = if checks.iter().any(|c| c.scales.includes(scale)) {
            println!("=== verify {} ===", exp.id());
            match registry::run_record(exp, scale) {
                Ok(rec) => {
                    let verdicts = oracle.judge(exp.id(), &rec, scale);
                    exp_rows.push(rec);
                    verdicts
                }
                Err(e) => {
                    // Flush everything collected so far below before
                    // surfacing the failure.
                    run_outcome = Err(anyhow::anyhow!("verify '{}': {e:#}", exp.id()));
                    break;
                }
            }
        } else {
            println!(
                "=== verify {} === every entry is out of scale at {scale:?}; not running",
                exp.id()
            );
            checks.iter().map(|c| c.skip()).collect()
        };
        for v in verdicts {
            match v.outcome {
                Outcome::Pass => n_pass += 1,
                Outcome::Skip => n_skip += 1,
                Outcome::Fail => {
                    n_fail += 1;
                    failures.push(v.message());
                }
            }
            println!("  [{}] {}", v.outcome.as_str().to_uppercase(), v.message());
            rows.push(v.record());
        }
    }
    let partial = if run_outcome.is_err() { ", PARTIAL — an experiment failed" } else { "" };
    if let Some(path) = json {
        write_atomic(path, render_records(&rows).as_bytes())?;
        println!("wrote {} ({} conformance rows{partial})", path.display(), rows.len());
    }
    if let Some(path) = experiments_json {
        write_atomic(path, render_records(&exp_rows).as_bytes())?;
        println!("wrote {} ({} experiment rows{partial})", path.display(), exp_rows.len());
    }
    run_outcome?;
    println!("conformance: {n_pass} pass, {n_fail} fail, {n_skip} skip");
    anyhow::ensure!(
        n_fail == 0,
        "paper conformance failed ({n_fail} checks):\n  {}",
        failures.join("\n  ")
    );
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"
# reference values
[fig9.final_loss]
expected = 1.5
abs = 0.25
rel = 0.1
note = "made up"

[fig9.rows.1.chi1]
expected = 13.14
abs = 0.05
scales = "quick"

[tab9.n_rows]
expected = 3
scales = "full"
"#;

    fn rec() -> Record {
        Record::new()
            .str("id", "fig9")
            .f64("final_loss", 1.6)
            .u64("n_rows", 2)
            .opt_f64("accuracy", None)
            .records(
                "rows",
                vec![
                    Record::new().f64("chi1", 0.94),
                    Record::new().f64("chi1", 13.16).str("topology", "ring"),
                ],
            )
    }

    #[test]
    fn parses_sections_tolerances_and_scales() {
        let o = Oracle::parse(SAMPLE).unwrap();
        assert_eq!(o.checks.len(), 3);
        let c = &o.checks[0];
        assert_eq!((c.id.as_str(), c.metric.as_str()), ("fig9", "final_loss"));
        assert_eq!(c.expected, 1.5);
        assert!((c.allowed() - 0.4).abs() < 1e-12, "abs 0.25 + rel 0.1*1.5");
        assert_eq!(c.scales, AppliesTo::Any);
        assert_eq!(c.note, "made up");
        assert_eq!(o.checks[1].metric, "rows.1.chi1");
        assert_eq!(o.checks[1].scales, AppliesTo::QuickOnly);
        assert_eq!(o.checks[2].allowed(), 0.0, "no tolerance keys = exact");
        assert_eq!(o.checks_for("fig9").len(), 2);
        assert!(o.checks_for("nope").is_empty());
    }

    #[test]
    fn parse_rejects_malformed() {
        for (bad, what) in [
            ("[final_loss]\nexpected = 1\n", "missing id.metric split"),
            ("[fig9.x]\nabs = 0.1\n", "no expected"),
            ("expected = 1\n", "key outside section"),
            ("[fig9.x]\nexpected = 1\nwat = 2\n", "unknown key"),
            ("[fig9.x]\nexpected = one\n", "non-numeric"),
            ("[fig9.x]\nexpected = 1\nscales = \"sometimes\"\n", "bad scale"),
            ("[fig9.x]\nexpected = 1\nabs = -1\n", "negative abs"),
            ("# nothing\n", "no checks"),
        ] {
            assert!(Oracle::parse(bad).is_err(), "{what}");
        }
    }

    #[test]
    fn extract_walks_fields_and_nested_rows() {
        let r = rec();
        assert_eq!(extract(&r, "final_loss"), Some(1.6));
        assert_eq!(extract(&r, "n_rows"), Some(2.0));
        assert_eq!(extract(&r, "rows.0.chi1"), Some(0.94));
        assert_eq!(extract(&r, "rows.1.chi1"), Some(13.16));
        assert_eq!(extract(&r, "accuracy"), None, "null is not a number");
        assert_eq!(extract(&r, "rows.1.topology"), None, "strings are not numeric");
        assert_eq!(extract(&r, "rows.7.chi1"), None, "index out of range");
        assert_eq!(extract(&r, "rows.chi1"), None, "rows need an index first");
        assert_eq!(extract(&r, "nope"), None);
        assert_eq!(extract(&r, "id.0"), None, "cannot path into a scalar");
    }

    #[test]
    fn extract_metric_resolves_ratios() {
        let r = rec();
        // 13.16 / 0.94 = 14.0
        let ratio = extract_metric(&r, "rows.1.chi1 / rows.0.chi1").unwrap();
        assert!((ratio - 14.0).abs() < 1e-9, "{ratio}");
        // Plain paths still resolve through the same entry point.
        assert_eq!(extract_metric(&r, "final_loss"), Some(1.6));
        // A missing side resolves to None, not a panic or a bogus value.
        assert_eq!(extract_metric(&r, "rows.1.chi1 / nope"), None);
        assert_eq!(extract_metric(&r, "nope / rows.1.chi1"), None);
        // Zero denominator: non-finite, so a judge would fail, not pass.
        let z = Record::new().f64("a", 1.0).f64("b", 0.0);
        assert!(!extract_metric(&z, "a / b").unwrap().is_finite());
    }

    #[test]
    fn ratio_checks_parse_and_judge() {
        let o = Oracle::parse(
            "[fig9.rows.1.chi1 / rows.0.chi1]\nexpected = 14.0\nabs = 0.5\n",
        )
        .unwrap();
        assert_eq!(o.checks[0].metric, "rows.1.chi1 / rows.0.chi1");
        let v = &o.judge("fig9", &rec(), Scale::Quick)[0];
        assert_eq!(v.outcome, Outcome::Pass, "{}", v.message());
        assert!((v.observed.unwrap() - 14.0).abs() < 1e-9);
    }

    #[test]
    fn judge_passes_inside_band_fails_outside() {
        let o = Oracle::parse(SAMPLE).unwrap();
        let verdicts = o.judge("fig9", &rec(), Scale::Quick);
        assert_eq!(verdicts.len(), 2);
        assert_eq!(verdicts[0].outcome, Outcome::Pass, "{}", verdicts[0].message());
        assert_eq!(verdicts[1].outcome, Outcome::Pass, "{}", verdicts[1].message());
        // At Full scale the quick-only row skips.
        let verdicts = o.judge("fig9", &rec(), Scale::Full);
        assert_eq!(verdicts[1].outcome, Outcome::Skip);
        assert!(verdicts[1].observed.is_none());
    }

    #[test]
    fn perturbed_metric_fails_with_observed_expected_and_tolerance() {
        let o = Oracle::parse(SAMPLE).unwrap();
        let mut r = rec();
        // Deliberately detune the headline metric past the band.
        for (k, v) in &mut r.fields {
            if k.as_str() == "final_loss" {
                *v = Value::F64(2.5);
            }
        }
        let v = &o.judge("fig9", &r, Scale::Quick)[0];
        assert_eq!(v.outcome, Outcome::Fail);
        assert!(v.margin().unwrap() > 0.0);
        let msg = v.message();
        assert!(msg.contains("observed 2.5"), "{msg}");
        assert!(msg.contains("expected 1.5"), "{msg}");
        assert!(msg.contains("0.4"), "tolerance band in message: {msg}");
    }

    #[test]
    fn nan_and_missing_metrics_fail() {
        let o = Oracle::parse("[x.loss]\nexpected = 1\nabs = 10\n").unwrap();
        let nan = Record::new().str("id", "x").f64("loss", f64::NAN);
        assert_eq!(o.judge("x", &nan, Scale::Quick)[0].outcome, Outcome::Fail);
        let missing = Record::new().str("id", "x");
        let v = &o.judge("x", &missing, Scale::Quick)[0];
        assert_eq!(v.outcome, Outcome::Fail);
        assert!(v.message().contains("metric missing"), "{}", v.message());
    }

    #[test]
    fn builtin_oracle_parses_and_every_id_is_registered() {
        let o = Oracle::builtin();
        assert!(!o.checks.is_empty());
        for c in &o.checks {
            assert!(
                registry::find(&c.id).is_some(),
                "oracle references unknown experiment '{}'",
                c.id
            );
            assert!(c.allowed().is_finite());
        }
        // Every registered experiment carries at least one reference row
        // — the whole registry surface is under contract.
        for exp in registry::all() {
            assert!(
                !o.checks_for(exp.id()).is_empty(),
                "experiment '{}' has no oracle entry",
                exp.id()
            );
        }
    }

    #[test]
    fn verdict_records_render_schema() {
        let o = Oracle::parse(SAMPLE).unwrap();
        let v = o.judge("fig9", &rec(), Scale::Quick);
        let text = render_records(&v.iter().map(Verdict::record).collect::<Vec<_>>());
        crate::testing::validate_json(&text).unwrap();
        assert!(text.contains("\"outcome\": \"pass\""));
        assert!(text.contains("\"metric\": \"rows.1.chi1\""));
        assert!(text.contains("\"margin\": "));
    }
}
