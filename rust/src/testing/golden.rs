//! Checked-in golden values with a bless-on-first-run flow.
//!
//! A golden file is a flat `key = "value"` list (comments and blank
//! lines allowed). [`check_or_bless`] compares an observed value against
//! the checked-in one:
//!
//! * value is the sentinel `"pending"` (or `A2CID2_BLESS=1` is set) —
//!   the file is rewritten in place with the observed value and the call
//!   reports [`GoldenStatus::Blessed`]; commit the updated file to pin it;
//! * value matches — [`GoldenStatus::Matched`];
//! * value differs — an error carrying both values and the re-bless
//!   instructions (a real regression, or an intentional change that must
//!   be re-blessed explicitly).
//!
//! This is how the replay determinism contract lives in `cargo test`
//! instead of only in CI: `tests/integration_replay.rs` drives the
//! `a2cid2 replay` churn scenario at two kernel-pool widths and pins the
//! FNV checksum of the final averaged parameters against
//! `rust/oracle/replay_golden.toml`.

use std::path::Path;
use std::sync::Mutex;

use crate::runtime::artifacts::write_atomic;

/// Serializes the read-modify-write bless cycle within this process.
/// `cargo test` runs tests in parallel threads; two tests blessing
/// DIFFERENT keys in the SAME file would otherwise interleave their
/// read → rewrite → publish cycles and one bless would silently revert
/// the other (each rename is atomic — the fixed-staging race is solved
/// in `write_atomic` — but the cycle as a whole is not). Cross-process
/// blessing remains last-writer-wins; the test harness only blesses
/// from one process.
static BLESS_LOCK: Mutex<()> = Mutex::new(());

/// How a golden comparison resolved (mismatches are `Err`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum GoldenStatus {
    /// The checked-in value matched the observation.
    Matched,
    /// The file held `"pending"` (or `A2CID2_BLESS=1` forced it) and was
    /// rewritten with the observed value.
    Blessed,
}

/// Compare `observed` against golden `key` in `path`, blessing pending
/// entries. See the module docs for the protocol.
pub fn check_or_bless(path: &Path, key: &str, observed: &str) -> crate::Result<GoldenStatus> {
    // Hold the process-wide bless lock for the whole read-check-rewrite
    // cycle (a poisoned lock just means another test's assert fired
    // while blessing; the file itself is never half-written).
    let _guard = BLESS_LOCK.lock().unwrap_or_else(|p| p.into_inner());
    let text = std::fs::read_to_string(path)
        .map_err(|e| anyhow::anyhow!("cannot read golden file {}: {e}", path.display()))?;
    let current = lookup(&text, key).ok_or_else(|| {
        anyhow::anyhow!(
            "golden key '{key}' not declared in {} (add `{key} = \"pending\"`)",
            path.display()
        )
    })?;
    let force = crate::config::env::knobs().bless;
    if current == "pending" || force {
        let updated = rewrite(&text, key, observed)?;
        write_atomic(path, updated.as_bytes())?;
        return Ok(GoldenStatus::Blessed);
    }
    anyhow::ensure!(
        current == observed,
        "golden '{key}' mismatch in {}:\n  checked-in: {current}\n  observed:   {observed}\n\
         If this change is intentional, re-bless with A2CID2_BLESS=1 (or set the entry \
         back to \"pending\") and commit the updated file.",
        path.display()
    );
    Ok(GoldenStatus::Matched)
}

/// The quoted value of `key` in the file text, if declared.
fn lookup(text: &str, key: &str) -> Option<String> {
    text.lines().find_map(|l| parse_line(l, key))
}

fn parse_line(line: &str, key: &str) -> Option<String> {
    let rest = line.trim().strip_prefix(key)?.trim_start();
    let value = rest.strip_prefix('=')?.trim();
    Some(value.strip_prefix('"')?.strip_suffix('"')?.to_string())
}

/// The file with `key`'s line replaced, everything else (comments,
/// ordering) preserved byte-for-byte.
fn rewrite(text: &str, key: &str, observed: &str) -> crate::Result<String> {
    anyhow::ensure!(
        !observed.contains('"') && !observed.contains('\n'),
        "golden values must be quote- and newline-free: {observed:?}"
    );
    let mut out = String::with_capacity(text.len());
    let mut replaced = false;
    for line in text.lines() {
        if !replaced && parse_line(line, key).is_some() {
            out.push_str(&format!("{key} = \"{observed}\""));
            replaced = true;
        } else {
            out.push_str(line);
        }
        out.push('\n');
    }
    anyhow::ensure!(replaced, "golden key '{key}' vanished mid-rewrite");
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn temp(name: &str, contents: &str) -> std::path::PathBuf {
        let path = std::env::temp_dir().join(format!("a2cid2_golden_{name}.toml"));
        std::fs::write(&path, contents).unwrap();
        path
    }

    const FILE: &str = "# golden checksums\nreplay_w1 = \"pending\"\nreplay_w4 = \"abc123\"\n";

    #[test]
    fn pending_blesses_and_then_matches() {
        let path = temp("bless", FILE);
        assert_eq!(check_or_bless(&path, "replay_w1", "deadbeef").unwrap(), GoldenStatus::Blessed);
        let text = std::fs::read_to_string(&path).unwrap();
        assert!(text.contains("replay_w1 = \"deadbeef\""), "{text}");
        assert!(text.starts_with("# golden checksums\n"), "comments survive: {text}");
        assert!(text.contains("replay_w4 = \"abc123\""), "other keys survive");
        assert_eq!(check_or_bless(&path, "replay_w1", "deadbeef").unwrap(), GoldenStatus::Matched);
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn mismatch_reports_both_values() {
        let path = temp("mismatch", FILE);
        let err = check_or_bless(&path, "replay_w4", "ffff").unwrap_err().to_string();
        assert!(err.contains("abc123"), "{err}");
        assert!(err.contains("ffff"), "{err}");
        assert!(err.contains("A2CID2_BLESS"), "{err}");
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn unknown_key_and_missing_file_error() {
        let path = temp("unknown", FILE);
        let err = check_or_bless(&path, "nope", "x").unwrap_err().to_string();
        assert!(err.contains("not declared"), "{err}");
        std::fs::remove_file(&path).ok();
        assert!(check_or_bless(&path, "replay_w1", "x").is_err());
    }

    #[test]
    fn concurrent_blessing_of_distinct_keys_loses_neither() {
        // Regression test for the bless write-race: N threads each bless
        // their own pending key in ONE shared golden file, concurrently.
        // Without the process-wide bless lock, interleaved
        // read → rewrite → publish cycles revert each other's updates.
        let n = 8;
        let mut contents = String::from("# shared oracle\n");
        for k in 0..n {
            contents.push_str(&format!("key_{k} = \"pending\"\n"));
        }
        let path = temp("race", &contents);
        let handles: Vec<_> = (0..n)
            .map(|k| {
                let path = path.clone();
                std::thread::spawn(move || {
                    check_or_bless(&path, &format!("key_{k}"), &format!("value_{k}")).unwrap()
                })
            })
            .collect();
        for h in handles {
            assert_eq!(h.join().unwrap(), GoldenStatus::Blessed);
        }
        // Every key holds ITS OWN observed value — nothing reverted to
        // pending, nothing overwritten by a sibling's cycle.
        let text = std::fs::read_to_string(&path).unwrap();
        for k in 0..n {
            assert!(
                text.contains(&format!("key_{k} = \"value_{k}\"")),
                "key_{k} lost its bless:\n{text}"
            );
        }
        assert!(text.starts_with("# shared oracle\n"), "comments survive");
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn rewrite_rejects_unquotable_values() {
        assert!(rewrite(FILE, "replay_w1", "a\"b").is_err());
        assert!(rewrite(FILE, "replay_w1", "a\nb").is_err());
    }
}
