//! Tab. 2 — communications per "step"/time-unit needed so graph
//! connectivity does not limit convergence, ours vs accelerated
//! synchronous methods (DeTAG/MSDA/OPAPC), on star / ring / complete.
//!
//! Appendix D: with a doubly-stochastic gossip matrix `W` and
//! `ℒ = I − W`, accelerated synchronous methods spend `|E|/√(1−θ)` edge
//! uses per step (θ = max(|λ₂|, |λₙ|) of W), while A²CiD² with
//! `Λ = √(χ₁[ℒ]χ₂[ℒ])·ℒ` spends `Tr(Λ)/2` and satisfies
//! `√(χ₁[Λ]χ₂[Λ]) = O(1)`. Paper asymptotics: star n^{3/2} vs n,
//! ring n² vs n², complete n² vs n.

use crate::graph::{Graph, Topology};
use crate::linalg::{sym_eig, Matrix};
use crate::metrics::{Record, Table};

use super::common::{GridRunner, Scale};
use super::Report;

/// Metropolis-weights gossip matrix (symmetric, doubly stochastic).
fn metropolis_laplacian(g: &Graph) -> (Matrix, Vec<f64>) {
    let mut rates = Vec::with_capacity(g.edges.len());
    for &(i, j) in &g.edges {
        rates.push(1.0 / (1.0 + g.degree(i).max(g.degree(j)) as f64));
    }
    (g.laplacian(&rates), rates)
}

pub struct Tab2Row {
    pub topology: &'static str,
    pub n: usize,
    pub sync_comms: f64,
    pub ours_comms: f64,
    pub paper_sync: &'static str,
    pub paper_ours: &'static str,
}

pub fn compute_row(topo: &Topology, n: usize) -> crate::Result<Tab2Row> {
    let g = Graph::build(topo, n)?;
    let (lap, rates) = metropolis_laplacian(&g);
    // θ of W = I − ℒ: eigenvalues 1 − λ(ℒ); exclude the kernel's 1.
    let eig = sym_eig(&lap);
    let theta = eig.values[1..]
        .iter()
        .map(|&l| (1.0 - l).abs())
        .fold(0.0f64, f64::max);
    let sync_comms = g.edges.len() as f64 / (1.0 - theta).max(1e-12).sqrt();
    // Ours: Λ = √(χ₁[ℒ]χ₂[ℒ])·ℒ ⇒ #comms per unit time = Tr(Λ)/2.
    let s = g.spectrum_with_rates(&rates);
    let ours_comms = s.chi_acc() * 0.5 * s.trace / 1.0;
    let (paper_sync, paper_ours) = match topo {
        Topology::Star => ("n^1.5", "n"),
        Topology::Ring => ("n^2", "n^2"),
        Topology::Complete => ("n^2", "n"),
        _ => ("-", "-"),
    };
    Ok(Tab2Row {
        topology: topo.name(),
        n,
        sync_comms,
        ours_comms,
        paper_sync,
        paper_ours,
    })
}

pub fn run(scale: Scale) -> crate::Result<(Vec<Tab2Row>, Vec<Table>)> {
    let grid: Vec<usize> = match scale {
        Scale::Quick => vec![16, 32],
        Scale::Full => vec![16, 32, 64, 128],
    };
    let mut points = Vec::new();
    for topo in [Topology::Star, Topology::Ring, Topology::Complete] {
        for &n in &grid {
            points.push((topo.clone(), n));
        }
    }
    // The eigensolves dominate (O(n³) per point at n = 128): fan the
    // (topology × n) grid across the runner pool.
    let rows = GridRunner::from_env().run(&points, |(topo, n)| compute_row(topo, *n))?;
    let mut table = Table::new(
        "Tab.2 — #communications per step/time-unit for connectivity-independent convergence",
        &[
            "topology",
            "n",
            "accel-sync |E|/sqrt(1-theta)",
            "ours Tr(L)*sqrt(chi1*chi2)/2",
            "paper sync",
            "paper ours",
        ],
    );
    for row in &rows {
        table.row(&[
            row.topology.into(),
            row.n.to_string(),
            format!("{:.0}", row.sync_comms),
            format!("{:.0}", row.ours_comms),
            row.paper_sync.into(),
            row.paper_ours.into(),
        ]);
    }
    Ok((rows, vec![table]))
}

pub fn report(scale: Scale) -> crate::Result<Report> {
    let (rows, tables) = run(scale)?;
    let records = rows
        .iter()
        .map(|r| {
            Record::new()
                .str("topology", r.topology)
                .u64("n", r.n as u64)
                .f64("sync_comms", r.sync_comms)
                .f64("ours_comms", r.ours_comms)
                .str("paper_sync", r.paper_sync)
                .str("paper_ours", r.paper_ours)
        })
        .collect();
    Ok(Report { tables, records, summary: Default::default() })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ours_never_worse_than_sync_asymptotics() {
        // Appendix D's bound: our comm complexity ≤ √2 × accel-sync.
        for topo in [Topology::Star, Topology::Ring, Topology::Complete] {
            let row = compute_row(&topo, 32).unwrap();
            assert!(
                row.ours_comms <= row.sync_comms * 2.0f64.sqrt() * 1.05,
                "{}: ours {} vs sync {}",
                row.topology,
                row.ours_comms,
                row.sync_comms
            );
        }
    }

    #[test]
    fn complete_graph_gap_grows_with_n() {
        // Paper: complete graph is n² (sync) vs n (ours) — the ratio must
        // grow roughly linearly with n.
        let r16 = compute_row(&Topology::Complete, 16).unwrap();
        let r64 = compute_row(&Topology::Complete, 64).unwrap();
        let gap16 = r16.sync_comms / r16.ours_comms;
        let gap64 = r64.sync_comms / r64.ours_comms;
        assert!(
            gap64 > gap16 * 2.0,
            "gap should grow ~4x from n=16 to n=64: {gap16} -> {gap64}"
        );
    }

    #[test]
    fn star_scalings() {
        // Star: ours ~ n, sync ~ n^{3/2}: ours/n bounded, sync/n grows.
        let r16 = compute_row(&Topology::Star, 16).unwrap();
        let r64 = compute_row(&Topology::Star, 64).unwrap();
        let ours_per_n_ratio = (r64.ours_comms / 64.0) / (r16.ours_comms / 16.0);
        assert!(ours_per_n_ratio < 2.5, "ours ~ n: ratio {ours_per_n_ratio}");
        let sync_per_n_ratio = (r64.sync_comms / 64.0) / (r16.sync_comms / 16.0);
        assert!(sync_per_n_ratio > 1.5, "sync ~ n^1.5: ratio {sync_per_n_ratio}");
    }
}
