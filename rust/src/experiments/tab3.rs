//! Tab. 3 — training times on the CIFAR-like task as n grows, ours
//! (asynchronous) vs AR-SGD. Paper (minutes): 20.9/10.5/5.2/2.7/1.5 vs
//! 21.9/11.1/6.6/3.2/1.8 for n = 4..64 — async is consistently faster
//! because nobody waits for stragglers, and both scale ~1/n at a fixed
//! total sample budget.

use crate::config::{Method, Task};
use crate::graph::Topology;
use crate::metrics::{Record, Table};

use super::common::{base_config, run_grid, GridPoint, Scale};
use super::{Report, Summary};

pub struct Tab3Row {
    pub n: usize,
    pub async_time: f64,
    pub ar_time: f64,
}

pub fn run(scale: Scale) -> crate::Result<(Vec<Tab3Row>, Vec<Table>)> {
    let mut cfg = base_config(scale);
    cfg.topology = Topology::Exponential;
    cfg.task = Task::CifarLike;
    cfg.compute_jitter = 0.1;
    // Fixed total sample budget: per-worker steps shrink with n.
    let total_steps: u64 = match scale {
        Scale::Quick => 2_400,
        Scale::Full => 12_800,
    };

    // One flat declared grid: (n × {async, AR}) in declaration order.
    let grid = scale.n_grid();
    let mut points = Vec::with_capacity(grid.len() * 2);
    for &n in &grid {
        for method in [Method::AsyncBaseline, Method::AllReduce] {
            let mut c = cfg.clone();
            c.n_workers = n;
            c.steps_per_worker = (total_steps / n as u64).max(10);
            c.method = method;
            points.push(GridPoint::new(c, cfg.seed));
        }
    }
    let outs = run_grid(&points)?;

    let mut rows = Vec::new();
    let mut table = Table::new(
        "Tab.3 — training time (virtual units) vs n, fixed total samples (paper: ours < AR, both ~1/n)",
        &["n", "ours t", "AR t", "speedup", "paper ours (min)", "paper AR (min)"],
    );
    let paper = [(4usize, 20.9, 21.9), (8, 10.5, 11.1), (16, 5.2, 6.6), (32, 2.7, 3.2), (64, 1.5, 1.8)];
    for (&n, pair) in grid.iter().zip(outs.chunks(2)) {
        let (ours, ar) = (&pair[0], &pair[1]);
        let (po, pa) = paper
            .iter()
            .find(|(pn, _, _)| *pn == n)
            .map(|(_, o, a)| (format!("{o}"), format!("{a}")))
            .unwrap_or(("-".into(), "-".into()));
        table.row(&[
            n.to_string(),
            format!("{:.1}", ours.t_end),
            format!("{:.1}", ar.t_end),
            format!("{:.2}x", ar.t_end / ours.t_end),
            po,
            pa,
        ]);
        rows.push(Tab3Row { n, async_time: ours.t_end, ar_time: ar.t_end });
    }
    Ok((rows, vec![table]))
}

pub fn report(scale: Scale) -> crate::Result<Report> {
    let (rows, tables) = run(scale)?;
    let records = rows
        .iter()
        .map(|r| {
            Record::new()
                .u64("n", r.n as u64)
                .f64("async_time", r.async_time)
                .f64("ar_time", r.ar_time)
                .f64("speedup", r.ar_time / r.async_time)
        })
        .collect();
    Ok(Report { tables, records, summary: Summary::default() })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn async_faster_and_scales_down() {
        let (rows, _) = run(Scale::Quick).unwrap();
        for r in &rows {
            assert!(
                r.async_time < r.ar_time,
                "n={}: async {} vs AR {}",
                r.n,
                r.async_time,
                r.ar_time
            );
        }
        // Doubling n roughly halves time at fixed total samples.
        let first = &rows[0];
        let last = rows.last().unwrap();
        let expect = first.n as f64 / last.n as f64;
        let got = last.async_time / first.async_time;
        assert!(
            (got / expect - 1.0).abs() < 0.5,
            "scaling {got} vs expected {expect}"
        );
    }
}
