//! Ablation — is the theory-given momentum rate η* = 1/(2√(χ₁χ₂))
//! actually the right operating point?
//!
//! DESIGN.md flags the (η, α̃) prescription of Prop. 3.6 as the design
//! choice to ablate: we sweep η over multiples of η* (adjusting α̃ = ½√(χ₁/χ₂)
//! held fixed, as in the paper) and measure the gossip-only consensus
//! decay time on the ring. The theory says η* balances the mixing speed
//! against the p2p step: too small degenerates to the baseline, too large
//! over-mixes x toward a stale x̃.

use crate::gossip::AcidParams;
use crate::graph::{Graph, Topology};
use crate::metrics::{Record, Table};

use super::common::{self, GridRunner, Scale};
use super::{Report, Summary};

/// Time for ‖πx‖² to contract 100× under gossip with momentum rate
/// `eta_mult × η*` (the shared [`common::gossip_decay_time`] probe with
/// a scaled prescription).
///
/// NOTE: unifying on the shared probe changed this measurement's event
/// stream (queue seed) and raised the cap horizon from 50n to 200n, so
/// absolute decay times — in particular the η = 0 arm, which used to hit
/// the old cap — are not comparable with pre-registry runs; the basin
/// shape around η* is what the table (and its test) pin.
fn decay_time(n: usize, eta_mult: f64, seed: u64) -> crate::Result<f64> {
    let graph = Graph::build(&Topology::Ring, n)?;
    let spectrum = graph.spectrum_with_rates(&graph.edge_rates(1.0));
    let theory = AcidParams::from_spectrum(&spectrum);
    let params = AcidParams {
        eta: theory.eta * eta_mult,
        alpha: theory.alpha,
        alpha_tilde: theory.alpha_tilde,
    };
    common::gossip_decay_time(n, &params, 1e-2, seed)
}

pub struct AblationRow {
    pub eta_mult: f64,
    pub decay_t: f64,
}

pub fn run(scale: Scale) -> crate::Result<(Vec<AblationRow>, Vec<Table>)> {
    let n = match scale {
        Scale::Quick => 16,
        Scale::Full => 64,
    };
    let mults = [0.0, 0.25, 0.5, 1.0, 2.0, 4.0, 8.0];
    let rows: Vec<AblationRow> = GridRunner::from_env()
        .run(&mults, |&eta_mult| {
            Ok(AblationRow { eta_mult, decay_t: decay_time(n, eta_mult, 5)? })
        })?;
    let star = rows
        .iter()
        .find(|r| r.eta_mult == 1.0)
        .expect("η* is in the grid")
        .decay_t;
    let mut table = Table::new(
        format!("Ablation — momentum rate η on the ring n={n} (η* = 1/(2·sqrt(chi1·chi2)))"),
        &["eta / eta*", "100x consensus decay time", "vs eta*"],
    );
    for row in &rows {
        table.row(&[
            row.eta_mult.to_string(),
            format!("{:.1}", row.decay_t),
            format!("{:+.0}%", 100.0 * (row.decay_t / star - 1.0)),
        ]);
    }
    Ok((rows, vec![table]))
}

pub fn report(scale: Scale) -> crate::Result<Report> {
    let (rows, tables) = run(scale)?;
    let records = rows
        .iter()
        .map(|r| Record::new().f64("eta_mult", r.eta_mult).f64("decay_t", r.decay_t))
        .collect();
    Ok(Report { tables, records, summary: Summary::default() })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn theory_eta_beats_extremes() {
        let (rows, _) = run(Scale::Quick).unwrap();
        let at = |m: f64| rows.iter().find(|r| r.eta_mult == m).unwrap().decay_t;
        let star = at(1.0);
        // η = 0 is the baseline (strictly slower on the ring) and a
        // severely over-mixed η is also slower — the prescription sits in
        // the basin.
        assert!(star < at(0.0), "eta* {star} vs baseline {}", at(0.0));
        assert!(star <= at(8.0) * 1.2, "eta* {star} vs 8x {}", at(8.0));
    }
}
