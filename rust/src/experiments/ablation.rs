//! Ablation — is the theory-given momentum rate η* = 1/(2√(χ₁χ₂))
//! actually the right operating point?
//!
//! DESIGN.md flags the (η, α̃) prescription of Prop. 3.6 as the design
//! choice to ablate: we sweep η over multiples of η* (adjusting α̃ = ½√(χ₁/χ₂)
//! held fixed, as in the paper) and measure the gossip-only consensus
//! decay time on the ring. The theory says η* balances the mixing speed
//! against the p2p step: too small degenerates to the baseline, too large
//! over-mixes x toward a stale x̃.

use crate::gossip::dynamics::comm_event;
use crate::gossip::{consensus_distance_sq, AcidParams, Mixer, WorkerState};
use crate::graph::{Graph, Topology};
use crate::metrics::Table;
use crate::rng::{standard_normal, Xoshiro256};
use crate::simulator::{EventKind, EventQueue};
use crate::util::two_mut;

use super::common::Scale;

/// Time for ‖πx‖² to contract 100× under gossip with momentum rate
/// `eta_mult × η*`.
fn decay_time(n: usize, eta_mult: f64, seed: u64) -> crate::Result<f64> {
    let dim = 32;
    let graph = Graph::build(&Topology::Ring, n)?;
    let rates = graph.edge_rates(1.0);
    let spectrum = graph.spectrum_with_rates(&rates);
    let theory = AcidParams::from_spectrum(&spectrum);
    let params = AcidParams {
        eta: theory.eta * eta_mult,
        alpha: theory.alpha,
        alpha_tilde: theory.alpha_tilde,
    };
    let mixer = Mixer::new(params.eta);
    let mut rng = Xoshiro256::seed_from_u64(seed);
    let mut workers: Vec<WorkerState> = (0..n)
        .map(|_| {
            WorkerState::new((0..dim).map(|_| standard_normal(&mut rng) as f32).collect())
        })
        .collect();
    let target = consensus_distance_sq(&workers) * 1e-2;
    let mut queue = EventQueue::new(&vec![1e-12; n], &rates, seed ^ 0xAB1A);
    let horizon = 400.0 * n as f64 / 8.0;
    let mut check_at = 0.25f64;
    while let Some(ev) = queue.next(horizon) {
        if let EventKind::Comm { edge } = ev.kind {
            let (i, j) = graph.edges[edge];
            let (a, b) = two_mut(&mut workers, i, j);
            comm_event(a, b, ev.t, &params, &mixer);
        }
        if ev.t >= check_at {
            check_at = ev.t + 0.25;
            let mut snap = workers.clone();
            for w in &mut snap {
                w.mix_to(ev.t, &mixer);
            }
            if consensus_distance_sq(&snap) < target {
                return Ok(ev.t);
            }
        }
    }
    Ok(horizon)
}

pub struct AblationRow {
    pub eta_mult: f64,
    pub decay_t: f64,
}

pub fn run(scale: Scale) -> crate::Result<(Vec<AblationRow>, Vec<Table>)> {
    let n = match scale {
        Scale::Quick => 16,
        Scale::Full => 64,
    };
    let mut table = Table::new(
        format!("Ablation — momentum rate η on the ring n={n} (η* = 1/(2·sqrt(chi1·chi2)))"),
        &["eta / eta*", "100x consensus decay time", "vs eta*"],
    );
    let mut rows = Vec::new();
    let star = decay_time(n, 1.0, 5)?;
    for mult in [0.0, 0.25, 0.5, 1.0, 2.0, 4.0, 8.0] {
        let t = if mult == 1.0 { star } else { decay_time(n, mult, 5)? };
        table.row(&[
            format!("{mult}"),
            format!("{t:.1}"),
            format!("{:+.0}%", 100.0 * (t / star - 1.0)),
        ]);
        rows.push(AblationRow { eta_mult: mult, decay_t: t });
    }
    Ok((rows, vec![table]))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn theory_eta_beats_extremes() {
        let (rows, _) = run(Scale::Quick).unwrap();
        let at = |m: f64| rows.iter().find(|r| r.eta_mult == m).unwrap().decay_t;
        let star = at(1.0);
        // η = 0 is the baseline (strictly slower on the ring) and a
        // severely over-mixed η is also slower — the prescription sits in
        // the basin.
        assert!(star < at(0.0), "eta* {star} vs baseline {}", at(0.0));
        assert!(star <= at(8.0) * 1.2, "eta* {star} vs 8x {}", at(8.0));
    }
}
