//! Scenario stress: A²CiD² vs the async baseline on a *time-varying*
//! network — a mid-run ring→exponential switch with 20% link dropout
//! over the middle half of the run.
//!
//! The paper claims A²CiD²'s benefit is largest "in poorly connected
//! networks"; this driver probes the harder, unexhibited case where the
//! connectivity itself changes mid-training. The whole network history is
//! a config string — any other history is a one-line change.

use crate::config::{Method, Scenario, Task};
use crate::metrics::{Record, Table};

use super::common::{base_config, run_grid, set_workers, GridPoint, Scale};
use super::{Report, Summary};

/// The demo scenario: ring phase, 20% links down over the middle half,
/// exponential graph from half-time on.
pub const DEMO_SCENARIO: &str = "ring@0,exponential@0.5;drop=0.2:0.25:0.75:7";

pub struct ScenarioRow {
    pub method: Method,
    pub final_loss: f64,
    pub final_consensus: f64,
    pub n_comms: u64,
}

pub fn run(scale: Scale) -> crate::Result<(Vec<ScenarioRow>, Vec<Table>)> {
    let mut cfg = base_config(scale);
    cfg.task = Task::CifarLike;
    cfg.comm_rate = 1.0;
    set_workers(&mut cfg, scale.n_max().min(16), scale);
    cfg.scenario = Some(Scenario::parse(DEMO_SCENARIO)?);

    let methods = [Method::AsyncBaseline, Method::Acid];
    let points: Vec<GridPoint> = methods
        .iter()
        .map(|&method| {
            let mut c = cfg.clone();
            c.method = method;
            GridPoint::new(c, cfg.seed)
        })
        .collect();
    let outs = run_grid(&points)?;

    let mut rows = Vec::new();
    let mut table = Table::new(
        format!(
            "Scenario — {} (n={}): A2CiD2 must hold up while the network changes under it",
            DEMO_SCENARIO, cfg.n_workers
        ),
        &["method", "final loss", "final consensus", "#comms"],
    );
    for (&method, out) in methods.iter().zip(&outs) {
        let consensus = out.final_consensus().unwrap_or(f64::NAN);
        table.row(&[
            method.name().into(),
            format!("{:.4}", out.final_loss),
            format!("{consensus:.4}"),
            out.n_comms.to_string(),
        ]);
        rows.push(ScenarioRow {
            method,
            final_loss: out.final_loss,
            final_consensus: consensus,
            n_comms: out.n_comms,
        });
    }
    Ok((rows, vec![table]))
}

pub fn report(scale: Scale) -> crate::Result<Report> {
    let (rows, tables) = run(scale)?;
    let records = rows
        .iter()
        .map(|r| {
            Record::new()
                .str("scenario", DEMO_SCENARIO)
                .str("method", r.method.name())
                .f64("final_loss", r.final_loss)
                .f64("final_consensus", r.final_consensus)
                .u64("n_comms", r.n_comms)
        })
        .collect();
    let summary = Summary {
        final_loss: rows.last().map(|r| r.final_loss),
        final_consensus: rows.last().map(|r| r.final_consensus),
        ..Summary::default()
    };
    Ok(Report { tables, records, summary })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn both_methods_survive_the_switch() {
        let (rows, tables) = run(Scale::Quick).unwrap();
        assert_eq!(rows.len(), 2);
        assert_eq!(tables.len(), 1);
        for row in &rows {
            assert!(row.final_loss.is_finite(), "{:?}", row.method);
            assert!(row.final_consensus.is_finite(), "{:?}", row.method);
            assert!(row.n_comms > 0, "{:?}", row.method);
        }
        // The momentum must not blow up under the switch: its consensus
        // stays in the same ballpark as the baseline's.
        let base = &rows[0];
        let acid = &rows[1];
        assert!(
            acid.final_consensus < (base.final_consensus + 1.0) * 50.0,
            "acid consensus {} vs baseline {}",
            acid.final_consensus,
            base.final_consensus
        );
    }
}
