//! Shared experiment harness: build the task, run the configured method,
//! evaluate on held-out data.

use std::sync::Arc;

use crate::config::{ExperimentConfig, Method, Task};
use crate::data::{Dataset, GaussianMixture, Sharding};
use crate::metrics::Series;
use crate::model::{Mlp, Model};
use crate::simulator::{run_allreduce, run_simulation, ArTimingConfig};

/// Experiment scale: quick for `cargo bench` smoke runs, full for the
/// paper-sized grids.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Scale {
    Quick,
    Full,
}

impl Scale {
    /// Read `A2CID2_BENCH_FULL` from the environment.
    pub fn from_env() -> Scale {
        if std::env::var("A2CID2_BENCH_FULL").map(|v| v == "1").unwrap_or(false) {
            Scale::Full
        } else {
            Scale::Quick
        }
    }

    /// Worker-count grid used by most sweeps.
    pub fn n_grid(&self) -> Vec<usize> {
        match self {
            Scale::Quick if cfg!(debug_assertions) => vec![4, 8],
            Scale::Quick => vec![4, 8, 16],
            Scale::Full => vec![4, 8, 16, 32, 64],
        }
    }

    /// Largest worker count (the paper's headline n = 64).
    pub fn n_max(&self) -> usize {
        match self {
            Scale::Quick if cfg!(debug_assertions) => 8,
            Scale::Quick => 16,
            Scale::Full => 64,
        }
    }

    /// Per-worker local step budget (configs not using the fixed total).
    pub fn steps(&self) -> u64 {
        match self {
            Scale::Quick if cfg!(debug_assertions) => 80,
            Scale::Quick => 300,
            Scale::Full => 800,
        }
    }

    /// Total gradient budget across all workers — the paper's protocol:
    /// "all methods access the same total amount of data samples", so
    /// per-worker steps shrink as n grows. (Unoptimized `cargo test`
    /// builds shrink the budgets so the experiment unit tests stay fast;
    /// benches always run optimized.)
    pub fn total_steps(&self) -> u64 {
        match self {
            Scale::Quick if cfg!(debug_assertions) => 960,
            Scale::Quick => 4_800,
            Scale::Full => 25_600,
        }
    }

    /// Seeds per configuration (the paper reports ±std over 3 runs).
    pub fn seeds(&self) -> Vec<u64> {
        match self {
            Scale::Quick => vec![0],
            Scale::Full => vec![0, 1, 2],
        }
    }
}

/// Everything a table/figure needs from one training run.
pub struct TrainOutcome {
    pub loss: Series,
    pub consensus: Option<Series>,
    pub final_loss: f64,
    /// Held-out accuracy of the averaged model (classification tasks).
    pub accuracy: Option<f64>,
    /// Virtual wall time of the run.
    pub t_end: f64,
    pub grads_per_worker: Vec<u64>,
    pub n_comms: u64,
    /// (χ₁, χ₂) of the run's Laplacian, if asynchronous.
    pub chis: Option<(f64, f64)>,
}

/// Build the train/test datasets for a task. Returns
/// `(train, test, model)` with the model evaluating on `train`.
/// Train and test are split from ONE sample so they share the same class
/// means (sampling twice would draw a fresh mixture — a different task).
pub fn build_task(task: Task, dataset_size: usize, seed: u64) -> (Arc<Dataset>, Arc<Dataset>, Arc<dyn Model>) {
    let (gen, hidden) = match task {
        Task::CifarLike => (GaussianMixture::cifar_like(), 32),
        Task::ImagenetLike => (GaussianMixture::imagenet_like(), 64),
        Task::Quadratic => panic!("use tab1's quadratic path"),
    };
    let test_size = (dataset_size / 4).max(1);
    let full = gen.sample(dataset_size + test_size, seed);
    let split = |lo: usize, hi: usize| Dataset {
        dim: full.dim,
        n_classes: full.n_classes,
        features: full.features[lo * full.dim..hi * full.dim].to_vec(),
        labels: full.labels[lo..hi].to_vec(),
    };
    let train = Arc::new(split(0, dataset_size));
    let test = Arc::new(split(dataset_size, dataset_size + test_size));
    let model: Arc<dyn Model> = Arc::new(Mlp::new(train.clone(), hidden, 5e-4));
    (train, test, model)
}

/// Run one configuration (any method) and evaluate.
pub fn train_once(cfg: &ExperimentConfig) -> crate::Result<TrainOutcome> {
    let (train, test, model) = build_task(cfg.task, cfg.dataset_size, cfg.seed ^ 0xBEEF);
    let shards = cfg.sharding.assign(&train, cfg.n_workers, cfg.seed);
    let test_idx: Vec<usize> = (0..test.len()).collect();
    // Accuracy is evaluated on held-out data via a model bound to `test`.
    let hidden = match cfg.task {
        Task::CifarLike => 32,
        Task::ImagenetLike => 64,
        Task::Quadratic => unreachable!(),
    };
    let eval_model = Mlp::new(test.clone(), hidden, 0.0);

    match cfg.method {
        Method::AllReduce => {
            let res = run_allreduce(cfg, model, &shards, &ArTimingConfig::default())?;
            let accuracy = eval_model.accuracy(&res.params, &test_idx);
            Ok(TrainOutcome {
                final_loss: res.final_loss(),
                loss: res.recorder.get("train_loss").cloned().unwrap_or_default(),
                consensus: None,
                accuracy,
                t_end: res.t_end,
                grads_per_worker: vec![res.grads_per_worker; cfg.n_workers],
                n_comms: 0,
                chis: None,
            })
        }
        _ => {
            let res = run_simulation(cfg, model, &shards)?;
            let accuracy = eval_model.accuracy(&res.avg_params, &test_idx);
            Ok(TrainOutcome {
                final_loss: res.final_loss(),
                loss: res.recorder.get("train_loss").cloned().unwrap_or_default(),
                consensus: res.recorder.get("consensus").cloned(),
                accuracy,
                t_end: res.t_end,
                grads_per_worker: res.grads_per_worker,
                n_comms: res.n_comms,
                chis: Some((res.spectrum.chi1, res.spectrum.chi2)),
            })
        }
    }
}

/// Set the worker count under the paper's fixed-total-sample protocol:
/// `steps_per_worker = total_steps / n`.
pub fn set_workers(cfg: &mut ExperimentConfig, n: usize, scale: Scale) {
    cfg.n_workers = n;
    cfg.steps_per_worker = (scale.total_steps() / n as u64).max(20);
}

/// Mean ± std of a closure over the scale's seeds.
pub fn over_seeds(
    scale: Scale,
    base: &ExperimentConfig,
    f: impl Fn(&TrainOutcome) -> f64,
) -> crate::Result<crate::metrics::Stats> {
    let mut vals = Vec::new();
    for seed in scale.seeds() {
        let mut cfg = base.clone();
        cfg.seed = seed;
        let out = train_once(&cfg)?;
        vals.push(f(&out));
    }
    Ok(crate::metrics::Stats::of(&vals))
}

/// Standard config for the sweeps.
pub fn base_config(scale: Scale) -> ExperimentConfig {
    ExperimentConfig {
        n_workers: 8,
        topology: crate::graph::Topology::Ring,
        method: Method::AsyncBaseline,
        task: Task::CifarLike,
        comm_rate: 1.0,
        batch_size: 16,
        base_lr: 0.1,
        momentum: 0.9,
        weight_decay: 5e-4,
        steps_per_worker: scale.steps(),
        sharding: Sharding::FullShuffled,
        dataset_size: 4096,
        seed: 0,
        compute_jitter: 0.1,
        scenario: None,
    }
}

/// Uniform "what a bench prints" view over the two experiment return
/// shapes (`Vec<Table>` or `(rows, Vec<Table>)`) — the `bench_main!`
/// macro renders any experiment through this.
pub trait IntoTables {
    fn into_tables(self) -> Vec<crate::metrics::Table>;
}

impl IntoTables for Vec<crate::metrics::Table> {
    fn into_tables(self) -> Vec<crate::metrics::Table> {
        self
    }
}

impl<T> IntoTables for (T, Vec<crate::metrics::Table>) {
    fn into_tables(self) -> Vec<crate::metrics::Table> {
        self.1
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scale_from_env_defaults_quick() {
        std::env::remove_var("A2CID2_BENCH_FULL");
        assert_eq!(Scale::from_env(), Scale::Quick);
    }

    #[test]
    fn train_once_all_methods() {
        let mut cfg = base_config(Scale::Quick);
        cfg.n_workers = 4;
        cfg.steps_per_worker = 60;
        cfg.dataset_size = 512;
        for method in [Method::AllReduce, Method::AsyncBaseline, Method::Acid] {
            cfg.method = method;
            let out = train_once(&cfg).unwrap();
            assert!(out.final_loss.is_finite(), "{method:?}");
            let acc = out.accuracy.unwrap();
            assert!(acc > 0.15, "{method:?}: acc={acc}");
            if method == Method::AllReduce {
                assert!(out.consensus.is_none());
            } else {
                assert!(out.chis.is_some());
            }
        }
    }

    #[test]
    fn over_seeds_aggregates() {
        let mut cfg = base_config(Scale::Quick);
        cfg.n_workers = 4;
        cfg.steps_per_worker = 40;
        cfg.dataset_size = 256;
        let stats = over_seeds(Scale::Quick, &cfg, |o| o.final_loss).unwrap();
        assert_eq!(stats.n, 1);
        assert!(stats.mean.is_finite());
    }
}
