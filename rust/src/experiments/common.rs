//! Shared experiment harness: build the task, run the configured method,
//! evaluate on held-out data — plus the [`GridRunner`] that fans every
//! module's declared grid across a fixed-width thread pool.

use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};

use crate::config::{ExperimentConfig, Method, Task};
use crate::data::{Dataset, GaussianMixture, Sharding};
use crate::gossip::dynamics::comm_event;
use crate::gossip::{consensus_distance_sq, AcidParams, Mixer, WorkerState};
use crate::graph::{Graph, Topology};
use crate::metrics::{Recorder, Series, Stats};
use crate::model::{Mlp, Model};
use crate::rng::{standard_normal, Xoshiro256};
use crate::simulator::{run_allreduce, run_simulation, ArTimingConfig, EventKind, EventQueue};
use crate::util::two_mut;

/// Experiment scale: quick for `cargo bench` smoke runs, full for the
/// paper-sized grids.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Scale {
    Quick,
    Full,
}

impl Scale {
    /// Read `A2CID2_BENCH_FULL` (via the process-wide
    /// [`crate::config::env::knobs`] cache).
    pub fn from_env() -> Scale {
        if crate::config::env::knobs().bench_full {
            Scale::Full
        } else {
            Scale::Quick
        }
    }

    /// Worker-count grid used by most sweeps.
    pub fn n_grid(&self) -> Vec<usize> {
        match self {
            Scale::Quick if cfg!(debug_assertions) => vec![4, 8],
            Scale::Quick => vec![4, 8, 16],
            Scale::Full => vec![4, 8, 16, 32, 64],
        }
    }

    /// Largest worker count (the paper's headline n = 64).
    pub fn n_max(&self) -> usize {
        match self {
            Scale::Quick if cfg!(debug_assertions) => 8,
            Scale::Quick => 16,
            Scale::Full => 64,
        }
    }

    /// Per-worker local step budget (configs not using the fixed total).
    pub fn steps(&self) -> u64 {
        match self {
            Scale::Quick if cfg!(debug_assertions) => 80,
            Scale::Quick => 300,
            Scale::Full => 800,
        }
    }

    /// Total gradient budget across all workers — the paper's protocol:
    /// "all methods access the same total amount of data samples", so
    /// per-worker steps shrink as n grows. (Unoptimized `cargo test`
    /// builds shrink the budgets so the experiment unit tests stay fast;
    /// benches always run optimized.)
    pub fn total_steps(&self) -> u64 {
        match self {
            Scale::Quick if cfg!(debug_assertions) => 960,
            Scale::Quick => 4_800,
            Scale::Full => 25_600,
        }
    }

    /// Seeds per configuration (the paper reports ±std over 3 runs).
    pub fn seeds(&self) -> Vec<u64> {
        match self {
            Scale::Quick => vec![0],
            Scale::Full => vec![0, 1, 2],
        }
    }
}

/// Everything a table/figure needs from one training run.
pub struct TrainOutcome {
    pub loss: Series,
    pub consensus: Option<Series>,
    pub final_loss: f64,
    /// Held-out accuracy of the averaged model (classification tasks).
    pub accuracy: Option<f64>,
    /// Virtual wall time of the run.
    pub t_end: f64,
    pub grads_per_worker: Vec<u64>,
    pub n_comms: u64,
    /// (χ₁, χ₂) of the run's Laplacian, if asynchronous.
    pub chis: Option<(f64, f64)>,
}

impl TrainOutcome {
    /// Last recorded consensus distance, if the run tracked one.
    pub fn final_consensus(&self) -> Option<f64> {
        self.consensus.as_ref().and_then(|s| s.last()).map(|(_, v)| v)
    }
}

/// One point of an experiment's declared grid: a full configuration plus
/// the seed that pins the run (it overwrites `cfg.seed` at execution).
#[derive(Clone, Debug)]
pub struct GridPoint {
    pub cfg: ExperimentConfig,
    pub seed: u64,
}

impl GridPoint {
    pub fn new(cfg: ExperimentConfig, seed: u64) -> GridPoint {
        GridPoint { cfg, seed }
    }
}

/// Deterministic parallel map over a declared grid.
///
/// Workers claim points from an atomic cursor and write results into the
/// slot matching the point's declaration index, so the returned `Vec` is
/// in declaration order regardless of pool width or scheduling — parallel
/// output is **bit-identical** to serial execution (pinned by
/// `grid_parallel_output_bit_identical_to_serial`), the same discipline
/// as `gossip::pool`'s fixed chunk boundaries. Errors are reported in
/// declaration order too (the first failing point wins).
pub struct GridRunner {
    width: usize,
}

impl GridRunner {
    /// Pool width from the environment: an explicit `A2CID2_POOL_THREADS`
    /// pins it exactly (the same override CI's determinism job uses for
    /// the kernel pool, so one knob governs every parallel surface;
    /// `1` = fully serial); otherwise one lane per available core,
    /// capped at 8 (each point is itself a full training run — a handful
    /// of lanes saturates the memory bus).
    pub fn from_env() -> GridRunner {
        let width = crate::config::env::knobs().pool_threads.unwrap_or_else(|| {
            std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1).min(8)
        });
        GridRunner::with_width(width)
    }

    /// Explicit width (tests pin 1 vs k to prove bit-identity).
    pub fn with_width(width: usize) -> GridRunner {
        GridRunner { width: width.max(1) }
    }

    pub fn width(&self) -> usize {
        self.width
    }

    /// Map `f` over `points`, collecting into declaration order. After a
    /// point fails, lanes stop claiming new points (matching the serial
    /// path's short-circuit instead of burning the rest of the grid) and
    /// the earliest-declared failure among the executed points is
    /// reported.
    pub fn run<P: Sync, R: Send>(
        &self,
        points: &[P],
        f: impl Fn(&P) -> crate::Result<R> + Sync,
    ) -> crate::Result<Vec<R>> {
        if self.width == 1 || points.len() <= 1 {
            return points.iter().map(&f).collect();
        }
        let cursor = AtomicUsize::new(0);
        let failed = AtomicBool::new(false);
        let slots: Vec<Mutex<Option<crate::Result<R>>>> =
            points.iter().map(|_| Mutex::new(None)).collect();
        std::thread::scope(|scope| {
            for _ in 0..self.width.min(points.len()) {
                scope.spawn(|| loop {
                    let i = cursor.fetch_add(1, Ordering::Relaxed);
                    if i >= points.len() || failed.load(Ordering::Relaxed) {
                        break;
                    }
                    let result = f(&points[i]);
                    if result.is_err() {
                        failed.store(true, Ordering::Relaxed);
                    }
                    *slots[i].lock().unwrap() = Some(result);
                });
            }
        });
        let mut results = Vec::with_capacity(points.len());
        let mut first_err = None;
        for slot in slots {
            // A `None` slot (skipped after a failure elsewhere) is only
            // reachable when some executed slot holds the error.
            match slot.into_inner().expect("grid slot lock poisoned") {
                Some(Ok(r)) if first_err.is_none() => results.push(r),
                Some(Err(e)) if first_err.is_none() => first_err = Some(e),
                _ => {}
            }
        }
        match first_err {
            None => Ok(results),
            Some(e) => Err(e),
        }
    }
}

/// Run every grid point through [`train_once`] across the grid-runner
/// pool (the standard path for training-based experiments).
pub fn run_grid(points: &[GridPoint]) -> crate::Result<Vec<TrainOutcome>> {
    GridRunner::from_env().run(points, |p| {
        let mut cfg = p.cfg.clone();
        cfg.seed = p.seed;
        train_once(&cfg)
    })
}

/// Mean ± std of a per-seed measurement — the paper's "± over 3 runs"
/// discipline in one place (tab1/fig4/tab4/tab5 used to hand-roll the
/// loop). Deliberately serial: every caller already sits inside an outer
/// [`GridRunner`] lane (a nested pool here would multiply concurrency
/// past the width cap and thrash the memory bus), and the seed count is
/// at most three.
pub fn aggregate_seeds(
    seeds: &[u64],
    run: impl Fn(u64) -> crate::Result<f64> + Sync,
) -> crate::Result<Stats> {
    let vals: Vec<f64> = seeds.iter().map(|&seed| run(seed)).collect::<crate::Result<_>>()?;
    Ok(Stats::of(&vals))
}

/// Build the train/test datasets for a task. Returns
/// `(train, test, model)` with the model evaluating on `train`.
/// Train and test are split from ONE sample so they share the same class
/// means (sampling twice would draw a fresh mixture — a different task).
pub fn build_task(task: Task, dataset_size: usize, seed: u64) -> (Arc<Dataset>, Arc<Dataset>, Arc<dyn Model>) {
    let (gen, hidden) = match task {
        Task::CifarLike => (GaussianMixture::cifar_like(), 32),
        Task::ImagenetLike => (GaussianMixture::imagenet_like(), 64),
        Task::Quadratic => panic!("use tab1's quadratic path"),
    };
    let test_size = (dataset_size / 4).max(1);
    let full = gen.sample(dataset_size + test_size, seed);
    let split = |lo: usize, hi: usize| Dataset {
        dim: full.dim,
        n_classes: full.n_classes,
        features: full.features[lo * full.dim..hi * full.dim].to_vec(),
        labels: full.labels[lo..hi].to_vec(),
    };
    let train = Arc::new(split(0, dataset_size));
    let test = Arc::new(split(dataset_size, dataset_size + test_size));
    let model: Arc<dyn Model> = Arc::new(Mlp::new(train.clone(), hidden, 5e-4));
    (train, test, model)
}

/// Run one configuration (any method) and evaluate.
pub fn train_once(cfg: &ExperimentConfig) -> crate::Result<TrainOutcome> {
    let (train, test, model) = build_task(cfg.task, cfg.dataset_size, cfg.seed ^ 0xBEEF);
    let shards = cfg.sharding.assign(&train, cfg.n_workers, cfg.seed);
    let test_idx: Vec<usize> = (0..test.len()).collect();
    // Accuracy is evaluated on held-out data via a model bound to `test`.
    let hidden = match cfg.task {
        Task::CifarLike => 32,
        Task::ImagenetLike => 64,
        Task::Quadratic => unreachable!(),
    };
    let eval_model = Mlp::new(test.clone(), hidden, 0.0);

    match cfg.method {
        Method::AllReduce => {
            let res = run_allreduce(cfg, model, &shards, &ArTimingConfig::default())?;
            let accuracy = eval_model.accuracy(&res.params, &test_idx);
            Ok(TrainOutcome {
                final_loss: res.final_loss(),
                loss: res.recorder.get("train_loss").cloned().unwrap_or_default(),
                consensus: None,
                accuracy,
                t_end: res.t_end,
                grads_per_worker: vec![res.grads_per_worker; cfg.n_workers],
                n_comms: 0,
                chis: None,
            })
        }
        _ => {
            let res = run_simulation(cfg, model, &shards)?;
            let accuracy = eval_model.accuracy(&res.avg_params, &test_idx);
            Ok(TrainOutcome {
                final_loss: res.final_loss(),
                loss: res.recorder.get("train_loss").cloned().unwrap_or_default(),
                consensus: res.recorder.get("consensus").cloned(),
                accuracy,
                t_end: res.t_end,
                grads_per_worker: res.grads_per_worker,
                n_comms: res.n_comms,
                chis: Some((res.spectrum.chi1, res.spectrum.chi2)),
            })
        }
    }
}

/// Set the worker count under the paper's fixed-total-sample protocol:
/// `steps_per_worker = total_steps / n`.
pub fn set_workers(cfg: &mut ExperimentConfig, n: usize, scale: Scale) {
    cfg.n_workers = n;
    cfg.steps_per_worker = (scale.total_steps() / n as u64).max(20);
}

/// [`aggregate_seeds`] over one training configuration: run it once per
/// seed (serially — see [`aggregate_seeds`]) and aggregate `metric` of
/// the outcome.
pub fn aggregate_config_seeds(
    seeds: &[u64],
    base: &ExperimentConfig,
    metric: impl Fn(&TrainOutcome) -> f64 + Sync,
) -> crate::Result<Stats> {
    aggregate_seeds(seeds, |seed| {
        let mut cfg = base.clone();
        cfg.seed = seed;
        Ok(metric(&train_once(&cfg)?))
    })
}

/// Fan a (variant × n) accuracy-style grid across the runner pool:
/// `mk(variant, n)` builds each cell's config and every cell aggregates
/// `metric` over `seeds` (tab4/tab5 share this scaffolding). Cells come
/// back variant-major in declaration order — chunk by `grid.len()` to
/// regroup per variant.
pub fn variant_grid_cells<V: Sync>(
    variants: &[V],
    grid: &[usize],
    seeds: &[u64],
    mk: impl Fn(&V, usize) -> ExperimentConfig + Sync,
    metric: impl Fn(&TrainOutcome) -> f64 + Sync,
) -> crate::Result<Vec<Stats>> {
    let mut points = Vec::with_capacity(variants.len() * grid.len());
    for vi in 0..variants.len() {
        for &n in grid {
            points.push((vi, n));
        }
    }
    GridRunner::from_env()
        .run(&points, |&(vi, n)| aggregate_config_seeds(seeds, &mk(&variants[vi], n), &metric))
}

/// Communication count at the first recorded sample at or after time `t`
/// — pairs with `Series::first_time_below` to turn a loss target into a
/// comms-to-target count (shared by `sweep` and `compare`).
pub fn comms_at(recorder: &Recorder, t: f64) -> Option<u64> {
    recorder
        .get("comms")?
        .points
        .iter()
        .find(|(tt, _)| *tt >= t)
        .map(|(_, v)| *v as u64)
}

/// Gossip-only consensus decay probe shared by `tab1` and `ablation`:
/// random initial `x` on the ring, communications at rate 1 per worker,
/// no gradients. Returns the first time ‖πx‖² drops below `target_frac`
/// of its initial value (capped at a generous horizon). `params` selects
/// the dynamic — `AcidParams::baseline()`, the theory's prescription, or
/// any scaled η the ablation wants to probe.
pub fn gossip_decay_time(
    n: usize,
    params: &AcidParams,
    target_frac: f64,
    seed: u64,
) -> crate::Result<f64> {
    let dim = 32;
    let graph = Graph::build(&Topology::Ring, n)?;
    let rates = graph.edge_rates(1.0);
    let mixer = Mixer::new(params.eta);
    let mut rng = Xoshiro256::seed_from_u64(seed);
    let mut workers: Vec<WorkerState> = (0..n)
        .map(|_| WorkerState::new((0..dim).map(|_| standard_normal(&mut rng) as f32).collect()))
        .collect();
    let target = consensus_distance_sq(&workers) * target_frac;
    // No gradient events: near-zero worker rates.
    let mut queue = EventQueue::new(&vec![1e-12; n], &rates, seed ^ 0xFEED);
    let horizon = 200.0 * n as f64; // generous upper bound
    let mut check_at = 0.25f64;
    while let Some(ev) = queue.next(horizon) {
        if let EventKind::Comm { edge } = ev.kind {
            let (i, j) = graph.edges[edge];
            let (a, b) = two_mut(&mut workers, i, j);
            comm_event(a, b, ev.t, params, &mixer);
        }
        if ev.t >= check_at {
            check_at = ev.t + 0.25;
            // Sync to a common time before measuring (lazy mixing).
            let mut snap = workers.clone();
            for w in &mut snap {
                w.mix_to(ev.t, &mixer);
            }
            if consensus_distance_sq(&snap) < target {
                return Ok(ev.t);
            }
        }
    }
    Ok(horizon)
}

/// Standard config for the sweeps.
pub fn base_config(scale: Scale) -> ExperimentConfig {
    ExperimentConfig {
        n_workers: 8,
        topology: crate::graph::Topology::Ring,
        method: Method::AsyncBaseline,
        task: Task::CifarLike,
        comm_rate: 1.0,
        batch_size: 16,
        base_lr: 0.1,
        momentum: 0.9,
        weight_decay: 5e-4,
        steps_per_worker: scale.steps(),
        sharding: Sharding::FullShuffled,
        dataset_size: 4096,
        seed: 0,
        compute_jitter: 0.1,
        scenario: None,
        algorithm: None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scale_from_env_defaults_quick() {
        std::env::remove_var("A2CID2_BENCH_FULL");
        assert_eq!(Scale::from_env(), Scale::Quick);
    }

    #[test]
    fn train_once_all_methods() {
        let mut cfg = base_config(Scale::Quick);
        cfg.n_workers = 4;
        cfg.steps_per_worker = 60;
        cfg.dataset_size = 512;
        for method in [Method::AllReduce, Method::AsyncBaseline, Method::Acid] {
            cfg.method = method;
            let out = train_once(&cfg).unwrap();
            assert!(out.final_loss.is_finite(), "{method:?}");
            let acc = out.accuracy.unwrap();
            assert!(acc > 0.15, "{method:?}: acc={acc}");
            if method == Method::AllReduce {
                assert!(out.consensus.is_none());
            } else {
                assert!(out.chis.is_some());
            }
        }
    }

    #[test]
    fn aggregate_config_seeds_aggregates() {
        let mut cfg = base_config(Scale::Quick);
        cfg.n_workers = 4;
        cfg.steps_per_worker = 40;
        cfg.dataset_size = 256;
        let stats =
            aggregate_config_seeds(&Scale::Quick.seeds(), &cfg, |o| o.final_loss).unwrap();
        assert_eq!(stats.n, 1);
        assert!(stats.mean.is_finite());
        let multi = aggregate_seeds(&[0, 1, 2], |seed| Ok(seed as f64)).unwrap();
        assert_eq!(multi.n, 3);
        assert!((multi.mean - 1.0).abs() < 1e-12);
    }

    /// Tiny 2-experiment smoke grid (two distinct methods/seeds): the
    /// parallel runner's output must be BIT-identical to serial
    /// execution — same final losses, same loss trajectories, in
    /// declaration order. This is the determinism contract `experiment
    /// all` and the benches rely on.
    #[test]
    fn grid_parallel_output_bit_identical_to_serial() {
        let mut cfg = base_config(Scale::Quick);
        cfg.n_workers = 4;
        cfg.steps_per_worker = 40;
        cfg.dataset_size = 256;
        let mut acid = cfg.clone();
        acid.method = Method::Acid;
        let points =
            vec![GridPoint::new(cfg, 3), GridPoint::new(acid, 4)];
        let run_at = |width: usize| {
            GridRunner::with_width(width)
                .run(&points, |p| {
                    let mut c = p.cfg.clone();
                    c.seed = p.seed;
                    train_once(&c)
                })
                .unwrap()
        };
        let serial = run_at(1);
        let parallel = run_at(4);
        assert_eq!(serial.len(), 2);
        for (s, p) in serial.iter().zip(&parallel) {
            assert_eq!(s.final_loss.to_bits(), p.final_loss.to_bits());
            assert_eq!(s.t_end.to_bits(), p.t_end.to_bits());
            assert_eq!(s.n_comms, p.n_comms);
            assert_eq!(s.loss.points.len(), p.loss.points.len());
            for ((ts, vs), (tp, vp)) in s.loss.points.iter().zip(&p.loss.points) {
                assert_eq!(ts.to_bits(), tp.to_bits());
                assert_eq!(vs.to_bits(), vp.to_bits());
            }
        }
        // The two points really are different workloads.
        assert_ne!(serial[0].final_loss.to_bits(), serial[1].final_loss.to_bits());
    }

    #[test]
    fn grid_runner_reports_the_failing_point() {
        // One failing point keeps the reported error deterministic even
        // with the early-stop (lanes stop claiming once a point fails).
        let points = vec![1u64, 2, 3, 4];
        let probe = |&p: &u64| -> crate::Result<u64> {
            if p == 2 {
                anyhow::bail!("point {p} failed")
            }
            Ok(p)
        };
        for width in [1, 4] {
            let err = GridRunner::with_width(width).run(&points, probe).unwrap_err();
            assert_eq!(err.to_string(), "point 2 failed", "width {width}");
        }
    }

    #[test]
    fn gossip_decay_accelerated_beats_baseline() {
        let graph = Graph::build(&Topology::Ring, 16).unwrap();
        let spectrum = graph.spectrum_with_rates(&graph.edge_rates(1.0));
        let base = gossip_decay_time(16, &AcidParams::baseline(), 1e-2, 3).unwrap();
        let acid =
            gossip_decay_time(16, &AcidParams::from_spectrum(&spectrum), 1e-2, 3).unwrap();
        assert!(acid < base, "acid {acid} vs baseline {base} on ring-16");
    }
}
