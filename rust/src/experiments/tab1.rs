//! Tab. 1 — convergence-rate scaling: χ₁ (baseline) vs √(χ₁χ₂) (A²CiD²).
//!
//! The paper's rate table separates the two methods through the network
//! factor χ. Two measurable consequences are reproduced on rings of
//! growing n (where χ₁ = Θ(n²) but √(χ₁χ₂) = Θ(n^{3/2})):
//!
//! 1. **Gossip decay time** — with communications only, the consensus
//!    distance contracts at rate ~1/χ₁ for plain randomized gossip and
//!    ~1/√(χ₁χ₂) with the continuous momentum ([12]'s accelerated
//!    randomized gossip, which A²CiD² embeds). We measure the time for
//!    ‖πx‖² to drop by 100× (the shared [`common::gossip_decay_time`]
//!    probe, mean ± std over the scale's seeds) — the baseline/A²CiD²
//!    time ratio should grow like √(χ₁/χ₂) ≈ Θ(√n).
//! 2. **Heterogeneous-SGD consensus plateau** — with per-worker optima
//!    perturbed (ζ² > 0) and a fixed step size, the stationary consensus
//!    error grows with the same χ factors (this is the ζ²(1+χ) term in
//!    Prop. 3.6's variance floor).

use crate::data::LinearRegression;
use crate::gossip::dynamics::{comm_event, WorkerState};
use crate::gossip::{consensus_distance_sq, AcidParams, Mixer};
use crate::graph::{Graph, Topology};
use crate::metrics::{Record, Stats, Table};
use crate::model::{Model, Quadratic};
use crate::rng::{standard_normal, Xoshiro256};
use crate::simulator::{EventKind, EventQueue};
use crate::util::two_mut;

use super::common::{self, aggregate_seeds, GridRunner, Scale};
use super::{Report, Summary};

/// One (n) measurement.
pub struct Tab1Row {
    pub n: usize,
    pub chi1: f64,
    pub chi_acc: f64,
    /// Time for gossip-only consensus to contract 100× (± over seeds).
    pub baseline_decay_t: Stats,
    pub acid_decay_t: Stats,
    /// Stationary consensus error under heterogeneous local SGD.
    pub baseline_plateau: f64,
    pub acid_plateau: f64,
}

/// Heterogeneous-SGD consensus plateau: each worker's quadratic optimum is
/// `w* + δ_i` (Σδ = 0); run baseline/acid at a common fixed γ and report
/// the stationary per-worker consensus error.
fn sgd_consensus_plateau(
    n: usize,
    accelerated: bool,
    gamma: f32,
    horizon: f64,
    seed: u64,
) -> crate::Result<(f64, f64, f64)> {
    let dim = 16;
    let graph = Graph::build(&Topology::Ring, n)?;
    let rates = graph.edge_rates(1.0);
    let spectrum = graph.spectrum_with_rates(&rates);
    let acid = if accelerated {
        AcidParams::from_spectrum(&spectrum)
    } else {
        AcidParams::baseline()
    };
    let mixer = Mixer::new(acid.eta);
    let models = build_local_models(n, dim, 1.0, seed);

    let mut workers: Vec<WorkerState> =
        (0..n).map(|_| WorkerState::new(vec![0.0; dim])).collect();
    let mut queue = EventQueue::new(&vec![1.0; n], &rates, seed ^ 0xC0FFEE);
    let mut rng = Xoshiro256::seed_from_u64(seed ^ 0xABCD);
    let mut grad = vec![0.0f32; dim];
    let mut batch = Vec::new();
    let mut plateau = Vec::new();
    let mut next_sample = 0.0f64;

    while let Some(ev) = queue.next(horizon) {
        match ev.kind {
            EventKind::Grad { worker } => {
                batch.clear();
                for _ in 0..8 {
                    batch.push(rng.gen_range(256));
                }
                models[worker].loss_grad(&workers[worker].x, &batch, &mut grad);
                workers[worker].apply_grad(ev.t, gamma, &grad, &mixer);
            }
            EventKind::Comm { edge } => {
                let (i, j) = graph.edges[edge];
                let (a, b) = two_mut(&mut workers, i, j);
                comm_event(a, b, ev.t, &acid, &mixer);
            }
        }
        if ev.t >= next_sample && ev.t > horizon * 0.6 {
            next_sample = ev.t + 0.5;
            let mut snap = workers.clone();
            for w in &mut snap {
                w.mix_to(ev.t, &mixer);
            }
            plateau.push(consensus_distance_sq(&snap) / n as f64);
        }
    }
    let p = if plateau.is_empty() {
        f64::NAN
    } else {
        plateau.iter().sum::<f64>() / plateau.len() as f64
    };
    Ok((p, spectrum.chi1, spectrum.chi_acc()))
}

/// Per-worker heterogeneous quadratics: shared `w*`, worker optima
/// `w* + δ_i` with `Σδ_i = 0`.
fn build_local_models(n: usize, dim: usize, hetero: f64, seed: u64) -> Vec<Quadratic> {
    let mut rng = Xoshiro256::seed_from_u64(seed);
    let base = LinearRegression { dim, noise: 0.05 }.sample(1, seed);
    let w_star = base.w_star;
    let mut deltas: Vec<Vec<f64>> = (0..n)
        .map(|_| (0..dim).map(|_| hetero * standard_normal(&mut rng)).collect())
        .collect();
    for d in 0..dim {
        let mean: f64 = deltas.iter().map(|v| v[d]).sum::<f64>() / n as f64;
        for row in &mut deltas {
            row[d] -= mean;
        }
    }
    (0..n)
        .map(|i| {
            let mut gen_rng = Xoshiro256::seed_from_u64(seed ^ ((i as u64 + 1) << 8));
            let mut w_i = w_star.clone();
            for d in 0..dim {
                w_i[d] += deltas[i][d] as f32;
            }
            let n_ex = 256;
            let mut features = Vec::with_capacity(n_ex * dim);
            let mut targets = Vec::with_capacity(n_ex);
            for _ in 0..n_ex {
                let mut y = 0.0f64;
                for &w in &w_i {
                    let x = standard_normal(&mut gen_rng);
                    features.push(x as f32);
                    y += w as f64 * x;
                }
                targets.push((y + 0.05 * standard_normal(&mut gen_rng)) as f32);
            }
            Quadratic::new(
                std::sync::Arc::new(crate::data::RegressionData {
                    dim,
                    features,
                    targets,
                    w_star: w_i,
                }),
                0.0,
            )
        })
        .collect()
}

/// The theory-prescribed parameters on the ring at rate 1.
fn ring_acid_params(n: usize) -> crate::Result<AcidParams> {
    let graph = Graph::build(&Topology::Ring, n)?;
    Ok(AcidParams::from_spectrum(&graph.spectrum_with_rates(&graph.edge_rates(1.0))))
}

pub fn run(scale: Scale) -> crate::Result<(Vec<Tab1Row>, Vec<Table>)> {
    let grid: Vec<usize> = match scale {
        Scale::Quick => vec![8, 16, 32],
        Scale::Full => vec![8, 16, 32, 48, 64],
    };
    let horizon = match scale {
        Scale::Quick => 150.0,
        Scale::Full => 400.0,
    };
    let gamma = 0.05f32;
    let seeds = scale.seeds();

    let rows = GridRunner::from_env().run(&grid, |&n| {
        let acid = ring_acid_params(n)?;
        let baseline_decay_t = aggregate_seeds(&seeds, |s| {
            common::gossip_decay_time(n, &AcidParams::baseline(), 1e-2, s ^ 7)
        })?;
        let acid_decay_t =
            aggregate_seeds(&seeds, |s| common::gossip_decay_time(n, &acid, 1e-2, s ^ 7))?;
        let (baseline_plateau, chi1, chi_acc) =
            sgd_consensus_plateau(n, false, gamma, horizon, 7)?;
        let (acid_plateau, _, _) = sgd_consensus_plateau(n, true, gamma, horizon, 7)?;
        Ok(Tab1Row {
            n,
            chi1,
            chi_acc,
            baseline_decay_t,
            acid_decay_t,
            baseline_plateau,
            acid_plateau,
        })
    })?;

    let mut table = Table::new(
        "Tab.1 — network-factor scaling on the ring (paper: chi1 vs sqrt(chi1*chi2))",
        &[
            "n",
            "chi1",
            "sqrt(chi1*chi2)",
            "gossip 100x decay t: base",
            "acid",
            "ratio",
            "theory sqrt(chi1/chi2)",
            "SGD consensus plateau: base",
            "acid",
        ],
    );
    for row in &rows {
        let chi2 = row.chi_acc * row.chi_acc / row.chi1;
        table.row(&[
            row.n.to_string(),
            format!("{:.1}", row.chi1),
            format!("{:.1}", row.chi_acc),
            row.baseline_decay_t.pm(1),
            row.acid_decay_t.pm(1),
            format!("{:.2}", row.baseline_decay_t.mean / row.acid_decay_t.mean),
            format!("{:.2}", (row.chi1 / chi2).sqrt()),
            format!("{:.4}", row.baseline_plateau),
            format!("{:.4}", row.acid_plateau),
        ]);
    }
    Ok((rows, vec![table]))
}

pub fn report(scale: Scale) -> crate::Result<Report> {
    let (rows, tables) = run(scale)?;
    let records = rows
        .iter()
        .map(|r| {
            Record::new()
                .u64("n", r.n as u64)
                .f64("chi1", r.chi1)
                .f64("chi_acc", r.chi_acc)
                .f64("baseline_decay_t", r.baseline_decay_t.mean)
                .f64("baseline_decay_t_std", r.baseline_decay_t.std)
                .f64("acid_decay_t", r.acid_decay_t.mean)
                .f64("acid_decay_t_std", r.acid_decay_t.std)
                .f64("decay_ratio", r.baseline_decay_t.mean / r.acid_decay_t.mean)
                .f64("baseline_plateau", r.baseline_plateau)
                .f64("acid_plateau", r.acid_plateau)
        })
        .collect();
    let summary = Summary {
        final_consensus: rows.last().map(|r| r.acid_plateau),
        ..Summary::default()
    };
    Ok(Report { tables, records, summary })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn decay(n: usize, accelerated: bool, seed: u64) -> f64 {
        let params = if accelerated {
            ring_acid_params(n).unwrap()
        } else {
            AcidParams::baseline()
        };
        common::gossip_decay_time(n, &params, 1e-2, seed).unwrap()
    }

    #[test]
    fn acid_gossip_decays_faster_at_scale() {
        // The core acceleration claim at the largest quick-ring.
        let bd = decay(32, false, 3);
        let ad = decay(32, true, 3);
        assert!(
            ad < bd,
            "acid decay {ad} should beat baseline {bd} on ring-32"
        );
    }

    #[test]
    fn decay_advantage_grows_with_n() {
        let r8 = decay(8, false, 5) / decay(8, true, 5);
        let r32 = decay(32, false, 5) / decay(32, true, 5);
        assert!(
            r32 > r8,
            "speedup should grow with n: ring8 {r8:.2} vs ring32 {r32:.2}"
        );
    }

    #[test]
    fn local_models_average_to_w_star() {
        let models = build_local_models(6, 8, 1.0, 3);
        let mean_w: Vec<f64> = (0..8)
            .map(|d| models.iter().map(|m| m.data.w_star[d] as f64).sum::<f64>() / 6.0)
            .collect();
        // Σδ = 0 ⇒ the mean of the local optima is the shared w*; verify
        // consistency by re-deriving it from any model minus its delta —
        // here simply check the means are finite and shared across seeds.
        let models2 = build_local_models(6, 8, 1.0, 3);
        for d in 0..8 {
            let mean2: f64 =
                models2.iter().map(|m| m.data.w_star[d] as f64).sum::<f64>() / 6.0;
            assert!((mean_w[d] - mean2).abs() < 1e-9);
        }
    }
}
