//! Tab. 1 — convergence-rate scaling: χ₁ (baseline) vs √(χ₁χ₂) (A²CiD²).
//!
//! The paper's rate table separates the two methods through the network
//! factor χ. Two measurable consequences are reproduced on rings of
//! growing n (where χ₁ = Θ(n²) but √(χ₁χ₂) = Θ(n^{3/2})):
//!
//! 1. **Gossip decay time** — with communications only, the consensus
//!    distance contracts at rate ~1/χ₁ for plain randomized gossip and
//!    ~1/√(χ₁χ₂) with the continuous momentum ([12]'s accelerated
//!    randomized gossip, which A²CiD² embeds). We measure the time for
//!    ‖πx‖² to drop by 100× — the baseline/A²CiD² time ratio should grow
//!    like √(χ₁/χ₂) ≈ Θ(√n).
//! 2. **Heterogeneous-SGD consensus plateau** — with per-worker optima
//!    perturbed (ζ² > 0) and a fixed step size, the stationary consensus
//!    error grows with the same χ factors (this is the ζ²(1+χ) term in
//!    Prop. 3.6's variance floor).

use crate::data::LinearRegression;
use crate::gossip::dynamics::{comm_event, WorkerState};
use crate::gossip::{consensus_distance_sq, AcidParams, Mixer};
use crate::graph::{Graph, Topology};
use crate::metrics::Table;
use crate::model::{Model, Quadratic};
use crate::rng::{standard_normal, Xoshiro256};
use crate::simulator::{EventKind, EventQueue};
use crate::util::two_mut;

use super::common::Scale;

/// One (n) measurement.
pub struct Tab1Row {
    pub n: usize,
    pub chi1: f64,
    pub chi_acc: f64,
    /// Time for gossip-only consensus to contract 100×.
    pub baseline_decay_t: f64,
    pub acid_decay_t: f64,
    /// Stationary consensus error under heterogeneous local SGD.
    pub baseline_plateau: f64,
    pub acid_plateau: f64,
}

/// Gossip-only: random initial x, communications at rate 1/worker, no
/// gradients. Returns the time at which ‖πx‖² first drops below
/// `target_frac` of its initial value.
fn gossip_decay_time(n: usize, accelerated: bool, target_frac: f64, seed: u64) -> crate::Result<f64> {
    let dim = 32;
    let graph = Graph::build(&Topology::Ring, n)?;
    let rates = graph.edge_rates(1.0);
    let spectrum = graph.spectrum_with_rates(&rates);
    let acid = if accelerated {
        AcidParams::from_spectrum(&spectrum)
    } else {
        AcidParams::baseline()
    };
    let mixer = Mixer::new(acid.eta);
    let mut rng = Xoshiro256::seed_from_u64(seed);
    let mut workers: Vec<WorkerState> = (0..n)
        .map(|_| {
            WorkerState::new((0..dim).map(|_| standard_normal(&mut rng) as f32).collect())
        })
        .collect();
    let start = consensus_distance_sq(&workers);
    let target = start * target_frac;
    // No gradient events: near-zero worker rates.
    let mut queue = EventQueue::new(&vec![1e-12; n], &rates, seed ^ 0xFEED);
    let horizon = 200.0 * n as f64; // generous upper bound
    let mut check_at = 0.25f64;
    while let Some(ev) = queue.next(horizon) {
        if let EventKind::Comm { edge } = ev.kind {
            let (i, j) = graph.edges[edge];
            let (a, b) = two_mut(&mut workers, i, j);
            comm_event(a, b, ev.t, &acid, &mixer);
        }
        if ev.t >= check_at {
            check_at = ev.t + 0.25;
            // Sync to a common time before measuring (lazy mixing).
            let mut snap = workers.clone();
            for w in &mut snap {
                w.mix_to(ev.t, &mixer);
            }
            if consensus_distance_sq(&snap) < target {
                return Ok(ev.t);
            }
        }
    }
    Ok(horizon)
}

/// Heterogeneous-SGD consensus plateau: each worker's quadratic optimum is
/// `w* + δ_i` (Σδ = 0); run baseline/acid at a common fixed γ and report
/// the stationary per-worker consensus error.
fn sgd_consensus_plateau(
    n: usize,
    accelerated: bool,
    gamma: f32,
    horizon: f64,
    seed: u64,
) -> crate::Result<(f64, f64, f64)> {
    let dim = 16;
    let graph = Graph::build(&Topology::Ring, n)?;
    let rates = graph.edge_rates(1.0);
    let spectrum = graph.spectrum_with_rates(&rates);
    let acid = if accelerated {
        AcidParams::from_spectrum(&spectrum)
    } else {
        AcidParams::baseline()
    };
    let mixer = Mixer::new(acid.eta);
    let models = build_local_models(n, dim, 1.0, seed);

    let mut workers: Vec<WorkerState> =
        (0..n).map(|_| WorkerState::new(vec![0.0; dim])).collect();
    let mut queue = EventQueue::new(&vec![1.0; n], &rates, seed ^ 0xC0FFEE);
    let mut rng = Xoshiro256::seed_from_u64(seed ^ 0xABCD);
    let mut grad = vec![0.0f32; dim];
    let mut batch = Vec::new();
    let mut plateau = Vec::new();
    let mut next_sample = 0.0f64;

    while let Some(ev) = queue.next(horizon) {
        match ev.kind {
            EventKind::Grad { worker } => {
                batch.clear();
                for _ in 0..8 {
                    batch.push(rng.gen_range(256));
                }
                models[worker].loss_grad(&workers[worker].x, &batch, &mut grad);
                workers[worker].apply_grad(ev.t, gamma, &grad, &mixer);
            }
            EventKind::Comm { edge } => {
                let (i, j) = graph.edges[edge];
                let (a, b) = two_mut(&mut workers, i, j);
                comm_event(a, b, ev.t, &acid, &mixer);
            }
        }
        if ev.t >= next_sample && ev.t > horizon * 0.6 {
            next_sample = ev.t + 0.5;
            let mut snap = workers.clone();
            for w in &mut snap {
                w.mix_to(ev.t, &mixer);
            }
            plateau.push(consensus_distance_sq(&snap) / n as f64);
        }
    }
    let p = if plateau.is_empty() {
        f64::NAN
    } else {
        plateau.iter().sum::<f64>() / plateau.len() as f64
    };
    Ok((p, spectrum.chi1, spectrum.chi_acc()))
}

/// Per-worker heterogeneous quadratics: shared `w*`, worker optima
/// `w* + δ_i` with `Σδ_i = 0`.
fn build_local_models(n: usize, dim: usize, hetero: f64, seed: u64) -> Vec<Quadratic> {
    let mut rng = Xoshiro256::seed_from_u64(seed);
    let base = LinearRegression { dim, noise: 0.05 }.sample(1, seed);
    let w_star = base.w_star;
    let mut deltas: Vec<Vec<f64>> = (0..n)
        .map(|_| (0..dim).map(|_| hetero * standard_normal(&mut rng)).collect())
        .collect();
    for d in 0..dim {
        let mean: f64 = deltas.iter().map(|v| v[d]).sum::<f64>() / n as f64;
        for row in &mut deltas {
            row[d] -= mean;
        }
    }
    (0..n)
        .map(|i| {
            let mut gen_rng = Xoshiro256::seed_from_u64(seed ^ ((i as u64 + 1) << 8));
            let mut w_i = w_star.clone();
            for d in 0..dim {
                w_i[d] += deltas[i][d] as f32;
            }
            let n_ex = 256;
            let mut features = Vec::with_capacity(n_ex * dim);
            let mut targets = Vec::with_capacity(n_ex);
            for _ in 0..n_ex {
                let mut y = 0.0f64;
                for &w in &w_i {
                    let x = standard_normal(&mut gen_rng);
                    features.push(x as f32);
                    y += w as f64 * x;
                }
                targets.push((y + 0.05 * standard_normal(&mut gen_rng)) as f32);
            }
            Quadratic::new(
                std::sync::Arc::new(crate::data::RegressionData {
                    dim,
                    features,
                    targets,
                    w_star: w_i,
                }),
                0.0,
            )
        })
        .collect()
}

pub fn run(scale: Scale) -> crate::Result<(Vec<Tab1Row>, Vec<Table>)> {
    let grid: Vec<usize> = match scale {
        Scale::Quick => vec![8, 16, 32],
        Scale::Full => vec![8, 16, 32, 48, 64],
    };
    let horizon = match scale {
        Scale::Quick => 150.0,
        Scale::Full => 400.0,
    };
    let gamma = 0.05f32;

    let mut rows = Vec::new();
    let mut table = Table::new(
        "Tab.1 — network-factor scaling on the ring (paper: chi1 vs sqrt(chi1*chi2))",
        &[
            "n",
            "chi1",
            "sqrt(chi1*chi2)",
            "gossip 100x decay t: base",
            "acid",
            "ratio",
            "theory sqrt(chi1/chi2)",
            "SGD consensus plateau: base",
            "acid",
        ],
    );
    for &n in &grid {
        let bd = gossip_decay_time(n, false, 1e-2, 7)?;
        let ad = gossip_decay_time(n, true, 1e-2, 7)?;
        let (bp, chi1, chi_acc) = sgd_consensus_plateau(n, false, gamma, horizon, 7)?;
        let (ap, _, _) = sgd_consensus_plateau(n, true, gamma, horizon, 7)?;
        let chi2 = chi_acc * chi_acc / chi1;
        table.row(&[
            n.to_string(),
            format!("{chi1:.1}"),
            format!("{chi_acc:.1}"),
            format!("{bd:.1}"),
            format!("{ad:.1}"),
            format!("{:.2}", bd / ad),
            format!("{:.2}", (chi1 / chi2).sqrt()),
            format!("{bp:.4}"),
            format!("{ap:.4}"),
        ]);
        rows.push(Tab1Row {
            n,
            chi1,
            chi_acc,
            baseline_decay_t: bd,
            acid_decay_t: ad,
            baseline_plateau: bp,
            acid_plateau: ap,
        });
    }
    Ok((rows, vec![table]))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn acid_gossip_decays_faster_at_scale() {
        // The core acceleration claim at the largest quick-ring.
        let bd = gossip_decay_time(32, false, 1e-2, 3).unwrap();
        let ad = gossip_decay_time(32, true, 1e-2, 3).unwrap();
        assert!(
            ad < bd,
            "acid decay {ad} should beat baseline {bd} on ring-32"
        );
    }

    #[test]
    fn decay_advantage_grows_with_n() {
        let r8 = {
            let b = gossip_decay_time(8, false, 1e-2, 5).unwrap();
            let a = gossip_decay_time(8, true, 1e-2, 5).unwrap();
            b / a
        };
        let r32 = {
            let b = gossip_decay_time(32, false, 1e-2, 5).unwrap();
            let a = gossip_decay_time(32, true, 1e-2, 5).unwrap();
            b / a
        };
        assert!(
            r32 > r8,
            "speedup should grow with n: ring8 {r8:.2} vs ring32 {r32:.2}"
        );
    }

    #[test]
    fn local_models_average_to_w_star() {
        let models = build_local_models(6, 8, 1.0, 3);
        let mean_w: Vec<f64> = (0..8)
            .map(|d| models.iter().map(|m| m.data.w_star[d] as f64).sum::<f64>() / 6.0)
            .collect();
        // Σδ = 0 ⇒ the mean of the local optima is the shared w*; verify
        // consistency by re-deriving it from any model minus its delta —
        // here simply check the means are finite and shared across seeds.
        let models2 = build_local_models(6, 8, 1.0, 3);
        for d in 0..8 {
            let mean2: f64 =
                models2.iter().map(|m| m.data.w_star[d] as f64).sum::<f64>() / 6.0;
            assert!((mean_w[d] - mean2).abs() < 1e-9);
        }
    }
}
