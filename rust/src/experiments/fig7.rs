//! Fig. 7 — pairing heat-maps from the *real-thread* runtime: the FIFO
//! availability matching should use each graph edge near-uniformly
//! (the assumption behind the theoretical χ values).

use std::sync::Arc;

use crate::config::Method;
use crate::data::{GaussianMixture, Sharding};
use crate::graph::{Graph, Topology};
use crate::metrics::Table;
use crate::model::{Logistic, Model};
use crate::optim::LrSchedule;
use crate::runtime::worker::{run_async, GradSource, RustGradSource, RuntimeOptions};

use super::common::Scale;
use super::Report;

/// Deliberately NOT grid-parallel: each topology run spawns the full
/// real-thread runtime (2 threads per worker + coordinator); nesting
/// that under the grid pool would oversubscribe the machine.
pub fn run(scale: Scale) -> crate::Result<Vec<Table>> {
    let (n, steps) = match scale {
        Scale::Quick => (8, 60),
        Scale::Full => (32, 200),
    };
    let ds = Arc::new(GaussianMixture::cifar_like().sample(1024, 3));
    let shards = Sharding::FullShuffled.assign(&ds, n, 1);
    let model: Arc<Logistic> = Arc::new(Logistic::new(ds, 0.0));

    let mut table = Table::new(
        "Fig.7 — pairing uniformity from the availability-queue coordinator",
        &["topology", "pairings", "non-edge pairings", "edge-use CV", "per-worker min..max"],
    );
    for topo in [Topology::Complete, Topology::Exponential, Topology::Ring] {
        let graph = Arc::new(Graph::build(&topo, n)?);
        let sources: Vec<Box<dyn GradSource>> = (0..n)
            .map(|w| {
                Box::new(RustGradSource::new(
                    model.clone() as Arc<dyn Model>,
                    shards.per_worker[w].clone(),
                    16,
                    w as u64,
                )) as Box<dyn GradSource>
            })
            .collect();
        let mut rng = crate::rng::Xoshiro256::seed_from_u64(0);
        let init = model.init_params(&mut rng);
        let opts = RuntimeOptions {
            comm_rate: 1.0,
            method: Method::AsyncBaseline,
            lr: LrSchedule::Constant { lr: 0.02 },
            momentum: 0.0,
            steps_per_worker: steps,
            seed: 0,
            ..Default::default()
        };
        let res = run_async(graph.clone(), sources, init, opts)?;
        // Count pairings on non-edges (must be zero).
        let mut non_edge = 0u64;
        for i in 0..n {
            for j in i + 1..n {
                if !graph.has_edge(i, j) {
                    non_edge += res.pairing.counts[i][j];
                }
            }
        }
        let per_worker = res.pairing.per_worker();
        println!(
            "Fig.7 heat-map — {} (n={n}, {} pairings):\n{}",
            topo.name(),
            res.pairing.total,
            res.pairing.render_heatmap()
        );
        table.row(&[
            topo.name().into(),
            res.pairing.total.to_string(),
            non_edge.to_string(),
            format!("{:.2}", res.pairing.edge_uniformity_cv(&graph)),
            format!(
                "{}..{}",
                per_worker.iter().min().unwrap(),
                per_worker.iter().max().unwrap()
            ),
        ]);
    }
    Ok(vec![table])
}

pub fn report(scale: Scale) -> crate::Result<Report> {
    Ok(Report::from_tables(run(scale)?))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn no_non_edge_pairings_and_reasonable_uniformity() {
        let tables = run(Scale::Quick).unwrap();
        for row in &tables[0].rows {
            assert_eq!(row[2], "0", "{}: non-edge pairings", row[0]);
        }
    }
}
