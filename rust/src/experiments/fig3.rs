//! Fig. 3 — complete graph: (a) the async baseline's training loss
//! degrades as n grows; (b) at the largest n, increasing the
//! communication rate closes the gap to All-Reduce.

use crate::config::{Method, Task};
use crate::graph::Topology;
use crate::metrics::Table;

use super::common::{base_config, run_grid, GridPoint, Scale};
use super::{Report, Summary};

/// Returns the headline scalar (panel (b)'s highest-rate async final
/// loss) alongside the two tables, so the JSON summary doesn't have to
/// re-parse a formatted table cell.
pub fn run(scale: Scale) -> crate::Result<(f64, Vec<Table>)> {
    let mut cfg = base_config(scale);
    cfg.topology = Topology::Complete;
    cfg.task = Task::CifarLike;

    // (a) loss vs n at 1 com/grad — one grid point per n.
    let grid = scale.n_grid();
    let points: Vec<GridPoint> = grid
        .iter()
        .map(|&n| {
            let mut c = cfg.clone();
            super::common::set_workers(&mut c, n, scale);
            c.method = Method::AsyncBaseline;
            c.comm_rate = 1.0;
            GridPoint::new(c, cfg.seed)
        })
        .collect();
    let mut ta = Table::new(
        "Fig.3a — complete graph, async baseline (paper: loss degrades with n)",
        &["n", "final loss", "consensus"],
    );
    for (&n, out) in grid.iter().zip(run_grid(&points)?) {
        let cons = out.final_consensus().unwrap_or(f64::NAN);
        ta.row(&[n.to_string(), format!("{:.4}", out.final_loss), format!("{cons:.4}")]);
    }

    // (b) n = max: AR reference + rate sweep, again one declared grid.
    super::common::set_workers(&mut cfg, scale.n_max(), scale);
    let rates = [1.0, 2.0, 4.0];
    let mut points = vec![{
        let mut c = cfg.clone();
        c.method = Method::AllReduce;
        GridPoint::new(c, cfg.seed)
    }];
    points.extend(rates.iter().map(|&rate| {
        let mut c = cfg.clone();
        c.method = Method::AsyncBaseline;
        c.comm_rate = rate;
        GridPoint::new(c, cfg.seed)
    }));
    let outs = run_grid(&points)?;
    let mut tb = Table::new(
        format!(
            "Fig.3b — complete graph n={}, rate sweep (paper: more com/grad -> AR gap closes)",
            cfg.n_workers
        ),
        &["variant", "com/grad", "final loss"],
    );
    tb.row(&["AR-SGD".into(), "-".into(), format!("{:.4}", outs[0].final_loss)]);
    for (&rate, out) in rates.iter().zip(&outs[1..]) {
        tb.row(&[
            "async baseline".into(),
            format!("{rate}"),
            format!("{:.4}", out.final_loss),
        ]);
    }
    let headline = outs.last().expect("rate sweep is non-empty").final_loss;
    Ok((headline, vec![ta, tb]))
}

pub fn report(scale: Scale) -> crate::Result<Report> {
    let (final_loss, tables) = run(scale)?;
    let summary = Summary { final_loss: Some(final_loss), ..Summary::default() };
    Ok(Report::from_tables(tables).with_summary(summary))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn produces_both_panels() {
        let (headline, tables) = run(Scale::Quick).unwrap();
        assert!(headline.is_finite());
        assert_eq!(tables.len(), 2);
        assert!(tables[0].rows.len() >= 2);
        assert_eq!(tables[1].rows.len(), 4);
    }
}
