//! Fig. 3 — complete graph: (a) the async baseline's training loss
//! degrades as n grows; (b) at the largest n, increasing the
//! communication rate closes the gap to All-Reduce.

use crate::config::{Method, Task};
use crate::graph::Topology;
use crate::metrics::Table;

use super::common::{base_config, train_once, Scale};

pub fn run(scale: Scale) -> crate::Result<Vec<Table>> {
    let mut cfg = base_config(scale);
    cfg.topology = Topology::Complete;
    cfg.task = Task::CifarLike;

    // (a) loss vs n at 1 com/grad.
    let mut ta = Table::new(
        "Fig.3a — complete graph, async baseline (paper: loss degrades with n)",
        &["n", "final loss", "consensus"],
    );
    for n in scale.n_grid() {
        super::common::set_workers(&mut cfg, n, scale);
        cfg.method = Method::AsyncBaseline;
        cfg.comm_rate = 1.0;
        let out = train_once(&cfg)?;
        let cons = out
            .consensus
            .as_ref()
            .and_then(|s| s.last())
            .map(|(_, v)| v)
            .unwrap_or(f64::NAN);
        ta.row(&[n.to_string(), format!("{:.4}", out.final_loss), format!("{cons:.4}")]);
    }

    // (b) n = max: rate sweep + AR reference.
    super::common::set_workers(&mut cfg, scale.n_max(), scale);
    let mut tb = Table::new(
        format!(
            "Fig.3b — complete graph n={}, rate sweep (paper: more com/grad -> AR gap closes)",
            cfg.n_workers
        ),
        &["variant", "com/grad", "final loss"],
    );
    cfg.method = Method::AllReduce;
    let ar = train_once(&cfg)?;
    tb.row(&["AR-SGD".into(), "-".into(), format!("{:.4}", ar.final_loss)]);
    for rate in [1.0, 2.0, 4.0] {
        cfg.method = Method::AsyncBaseline;
        cfg.comm_rate = rate;
        let out = train_once(&cfg)?;
        tb.row(&[
            "async baseline".into(),
            format!("{rate}"),
            format!("{:.4}", out.final_loss),
        ]);
    }
    Ok(vec![ta, tb])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn produces_both_panels() {
        let tables = run(Scale::Quick).unwrap();
        assert_eq!(tables.len(), 2);
        assert!(tables[0].rows.len() >= 2);
        assert_eq!(tables[1].rows.len(), 4);
    }
}
