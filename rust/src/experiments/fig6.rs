//! Fig. 6 — the three implemented topologies and their (χ₁, χ₂) at
//! 1 com/∇ per worker. Paper values for n = 16: complete (1, 1),
//! exponential (2, 1), ring (13, 1).

use crate::graph::{Graph, Topology};
use crate::metrics::Table;

use super::common::Scale;
use super::Report;

pub fn run(scale: Scale) -> crate::Result<Vec<Table>> {
    let n = 16; // Fig. 6 is drawn at n = 16 regardless of scale.
    let mut table = Table::new(
        "Fig.6 — graph topologies, (chi1, chi2) at 1 com/grad (paper: (1,1) / (2,1) / (13,1))",
        &["topology", "n", "|E|", "degree", "chi1", "chi2", "sqrt(chi1*chi2)", "paper (chi1,chi2)"],
    );
    let paper = [("complete", "(1, 1)"), ("exponential", "(2, 1)"), ("ring", "(13, 1)")];
    for (topo, (_, paper_val)) in [Topology::Complete, Topology::Exponential, Topology::Ring]
        .iter()
        .zip(paper)
    {
        let g = Graph::build(topo, n)?;
        let s = g.spectrum(1.0);
        let degs: Vec<usize> = (0..n).map(|i| g.degree(i)).collect();
        let deg_str = if degs.iter().all(|&d| d == degs[0]) {
            degs[0].to_string()
        } else {
            format!("{}..{}", degs.iter().min().unwrap(), degs.iter().max().unwrap())
        };
        table.row(&[
            topo.name().into(),
            n.to_string(),
            g.edges.len().to_string(),
            deg_str,
            format!("{:.2}", s.chi1),
            format!("{:.2}", s.chi2),
            format!("{:.2}", s.chi_acc()),
            paper_val.into(),
        ]);
    }

    // Extension: the same functionals at the scale's largest n, showing
    // the Θ(n²) vs Θ(n^{3/2}) growth that drives Fig. 4.
    let mut t2 = Table::new(
        "Fig.6 (extension) — chi growth with n on the ring",
        &["n", "chi1", "sqrt(chi1*chi2)", "chi1/n^2", "sqrt(chi1*chi2)/n^1.5"],
    );
    let mut ns = vec![8usize, 16, 32, scale.n_max()];
    ns.dedup();
    for n in ns {
        let g = Graph::build(&Topology::Ring, n)?;
        let s = g.spectrum(1.0);
        t2.row(&[
            n.to_string(),
            format!("{:.1}", s.chi1),
            format!("{:.1}", s.chi_acc()),
            format!("{:.4}", s.chi1 / (n * n) as f64),
            format!("{:.4}", s.chi_acc() / (n as f64).powf(1.5)),
        ]);
    }
    Ok(vec![table, t2])
}

pub fn report(scale: Scale) -> crate::Result<Report> {
    Ok(Report::from_tables(run(scale)?))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matches_paper_fig6_values() {
        let tables = run(Scale::Quick).unwrap();
        assert_eq!(tables.len(), 2);
        // chi values are asserted precisely in graph::tests; here check
        // the table carries the three topologies.
        assert_eq!(tables[0].rows.len(), 3);
    }
}
