//! Experiment drivers — one module per table/figure of the paper.
//!
//! Every module exposes `run(scale) -> Vec<Table>`: it executes the
//! workload, prints the regenerated rows next to the paper's reference
//! numbers, and returns the tables so benches, the CLI, and the tests
//! share one code path. `Scale::Quick` (the `cargo bench` default) shrinks
//! worker counts and step budgets to finish in seconds; `Scale::Full`
//! (`A2CID2_BENCH_FULL=1`) runs the paper-sized grids.
//!
//! | Module | Paper item | What it shows |
//! |---|---|---|
//! | [`fig1`]  | Fig. 1  | A²CiD² ≈ doubling the comm rate (ring, large n) |
//! | [`fig2`]  | Fig. 2  | sync vs async worker timelines / idle time |
//! | [`fig3`]  | Fig. 3  | complete graph: loss degrades with n; rate closes the gap |
//! | [`fig4`]  | Fig. 4  | ring: w/ vs w/o A²CiD² across n |
//! | [`fig5`]  | Fig. 5  | harder task: loss + consensus, A²CiD² vs 2× rate |
//! | [`fig6`]  | Fig. 6  | topologies and their (χ₁, χ₂) |
//! | [`fig7`]  | Fig. 7  | pairing heat-map ≈ uniform neighbor selection |
//! | [`tab1`]  | Tab. 1  | time-to-ε scaling: χ₁ (baseline) vs √(χ₁χ₂) (A²CiD²) |
//! | [`tab2`]  | Tab. 2  | #comms per unit time: star/ring/complete |
//! | [`tab3`]  | Tab. 3  | training times vs n, ours vs AR-SGD |
//! | [`tab4`]  | Tab. 4  | CIFAR-like accuracy across 3 graphs × n |
//! | [`tab5`]  | Tab. 5  | ImageNet-like accuracy on the ring, rates 1 & 2 |
//! | [`tab6`]  | Tab. 6  | wall time + #∇ slowest/fastest worker |
//!
//! Beyond the paper: [`scenario`] stresses A²CiD² on *time-varying*
//! networks (mid-run topology switch + link dropout) — conditions the
//! paper's "poorly connected networks" claim is about but its experiments
//! never exercise — and [`sweep`] charts the dropout × switch-time grid
//! comparing per-phase adaptive (η, α̃) against frozen phase-0 parameters
//! (emitting the machine-readable `BENCH_sweep.json`).

pub mod ablation;
pub mod common;
pub mod fig1;
pub mod fig2;
pub mod fig3;
pub mod fig4;
pub mod fig5;
pub mod fig6;
pub mod fig7;
pub mod scenario;
pub mod sweep;
pub mod tab1;
pub mod tab2;
pub mod tab3;
pub mod tab4;
pub mod tab5;
pub mod tab6;

pub use common::{train_once, IntoTables, Scale, TrainOutcome};

/// Generate a bench `main` for one experiment module: run it at the
/// env-selected scale, print its tables, report the elapsed time. Every
/// `rust/benches/<exp>.rs` target is exactly one invocation of this (they
/// used to be 14 copies of the same 11-line stub).
#[macro_export]
macro_rules! bench_main {
    ($exp:ident) => {
        fn main() {
            use $crate::experiments::IntoTables;
            let scale = $crate::experiments::Scale::from_env();
            let t0 = std::time::Instant::now();
            let tables = $crate::experiments::$exp::run(scale)
                .expect(stringify!($exp))
                .into_tables();
            for t in tables {
                t.print();
            }
            println!(
                "[{}] completed in {:.1}s at {scale:?} scale",
                stringify!($exp),
                t0.elapsed().as_secs_f64()
            );
        }
    };
}
