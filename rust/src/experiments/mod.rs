//! Experiment drivers — one module per table/figure of the paper.
//!
//! Every module exposes `run(scale)` (its typed rows + tables) and a
//! `report(scale) -> Report` wrapper the [`registry`] resolves by id:
//! the CLI (`a2cid2 experiment all [--filter SUBSTR] [--json PATH]`),
//! every `rust/benches/*.rs` target, and the tests all launch
//! experiments through the same registry entry. Grids fan out across
//! the deterministic [`common::GridRunner`] pool (declaration-order
//! collection ⇒ parallel output bit-identical to serial), and every run
//! leaves machine-readable [`crate::metrics::Record`]s behind —
//! consolidated into `BENCH_experiments.json` by `experiment all
//! --json`. `Scale::Quick` (the `cargo bench` default) shrinks worker
//! counts and step budgets to finish in seconds; `Scale::Full`
//! (`A2CID2_BENCH_FULL=1`, resolved once per process by
//! [`registry::scale`]) runs the paper-sized grids.
//!
//! The table below is regenerated from the registry
//! (`doc_table_matches_registry` fails on drift):
//!
//! | Module | Paper item | What it shows |
//! |---|---|---|
//! | [`fig1`]     | Fig. 1 | A²CiD² ≈ doubling the comm rate (ring, large n) |
//! | [`fig2`]     | Fig. 2 | sync vs async worker timelines / idle time |
//! | [`fig3`]     | Fig. 3 | complete graph: loss degrades with n; rate closes the gap |
//! | [`fig4`]     | Fig. 4 | ring: w/ vs w/o A²CiD² across n |
//! | [`fig5`]     | Fig. 5 | harder task: loss + consensus, A²CiD² vs 2× rate |
//! | [`fig6`]     | Fig. 6 | topologies and their (χ₁, χ₂) |
//! | [`fig7`]     | Fig. 7 | pairing heat-map ≈ uniform neighbor selection |
//! | [`tab1`]     | Tab. 1 | time-to-ε scaling: χ₁ (baseline) vs √(χ₁χ₂) (A²CiD²) |
//! | [`tab2`]     | Tab. 2 | #comms per unit time: star/ring/complete |
//! | [`tab3`]     | Tab. 3 | training times vs n, ours vs AR-SGD |
//! | [`tab4`]     | Tab. 4 | CIFAR-like accuracy across 3 graphs × n |
//! | [`tab5`]     | Tab. 5 | ImageNet-like accuracy on the ring, rates 1 & 2 |
//! | [`tab6`]     | Tab. 6 | wall time + #∇ slowest/fastest worker |
//! | [`ablation`] | beyond | momentum-rate η sweep around the theory's η* |
//! | [`scaling`]  | beyond | massive fleets: cluster_ring(k,m) χ₁ vs flat ring, multiplexed to 10⁵+ |
//! | [`scenario`] | beyond | A²CiD² across a mid-run topology switch + dropout |
//! | [`sweep`]    | beyond | dropout × switch × churn × adaptive grid |
//! | [`compare`]  | beyond | algorithm zoo head-to-head: consensus race + training, comms-to-target per arm |
//!
//! The beyond-paper drivers stress what the paper's experiments never
//! exercise: [`scenario`] runs A²CiD² on *time-varying* networks,
//! [`ablation`] probes the (η, α̃) prescription, and [`sweep`] charts the
//! dropout × switch-time × churn grid comparing per-phase adaptive
//! parameters against frozen phase-0 values (maintaining the
//! machine-readable `BENCH_sweep.json`). [`compare`] races the whole
//! algorithm zoo (`adpsgd`, `a2cid2`, `localsgd:H`, `allreduce`) on
//! shared seeded workloads, one `BENCH_compare.json` row per arm.
//!
//! Every registered id is under the paper-conformance contract:
//! `a2cid2 verify <id|all>` diffs the consolidated record against the
//! checked-in oracle (`rust/oracle/paper.toml`, see
//! [`crate::testing::oracle`]) and emits `BENCH_conformance.json`.

pub mod ablation;
pub mod common;
pub mod compare;
pub mod fig1;
pub mod fig2;
pub mod fig3;
pub mod fig4;
pub mod fig5;
pub mod fig6;
pub mod fig7;
pub mod registry;
pub mod scaling;
pub mod scenario;
pub mod sweep;
pub mod tab1;
pub mod tab2;
pub mod tab3;
pub mod tab4;
pub mod tab5;
pub mod tab6;

pub use common::{
    aggregate_seeds, run_grid, train_once, GridPoint, GridRunner, Scale, TrainOutcome,
};
pub use registry::{Experiment, Report, Summary};

/// Generate a bench `main` for one experiment module: resolve the module
/// through [`crate::experiments::registry`] (same entry the CLI uses),
/// run it at the process-wide scale, print its tables, maintain its
/// artifact, report the elapsed time. Every `rust/benches/<exp>.rs`
/// target is exactly one invocation of this.
#[macro_export]
macro_rules! bench_main {
    ($exp:ident) => {
        fn main() {
            $crate::experiments::registry::bench_entry(stringify!($exp));
        }
    };
}
