//! Fig. 1 — "Adding A²CiD² has the same effect as doubling the
//! communication rate" (ring graph, large n).
//!
//! Three runs on the ring at the scale's largest n:
//! baseline @ rate 1, baseline @ rate 2, A²CiD² @ rate 1. The paper's
//! claim is that the A²CiD²@1 loss curve tracks the baseline@2 curve, both
//! well below baseline@1.

use crate::config::{Method, Task};
use crate::graph::Topology;
use crate::metrics::{Record, Table};

use super::common::{base_config, run_grid, GridPoint, Scale, TrainOutcome};
use super::{Report, Summary};

pub struct Fig1 {
    pub baseline_1x: TrainOutcome,
    pub baseline_2x: TrainOutcome,
    pub acid_1x: TrainOutcome,
}

/// The three variants as (label, method, rate) in declaration order.
const VARIANTS: [(&str, Method, f64); 3] = [
    ("async baseline", Method::AsyncBaseline, 1.0),
    ("async baseline", Method::AsyncBaseline, 2.0),
    ("A2CiD2", Method::Acid, 1.0),
];

pub fn run(scale: Scale) -> crate::Result<(Fig1, Vec<Table>)> {
    let mut cfg = base_config(scale);
    cfg.topology = Topology::Ring;
    cfg.task = Task::ImagenetLike;
    super::common::set_workers(&mut cfg, scale.n_max(), scale);

    let points: Vec<GridPoint> = VARIANTS
        .iter()
        .map(|&(_, method, rate)| {
            let mut c = cfg.clone();
            c.method = method;
            c.comm_rate = rate;
            GridPoint::new(c, cfg.seed)
        })
        .collect();
    let mut outs = run_grid(&points)?.into_iter();
    let (baseline_1x, baseline_2x, acid_1x) = (
        outs.next().expect("baseline@1"),
        outs.next().expect("baseline@2"),
        outs.next().expect("acid@1"),
    );

    let mut table = Table::new(
        format!(
            "Fig.1 — ring n={}, train loss (paper: A2CiD2@1 tracks baseline@2)",
            cfg.n_workers
        ),
        &["variant", "com/grad", "final loss", "final consensus"],
    );
    for ((name, _, rate), out) in
        VARIANTS.iter().zip([&baseline_1x, &baseline_2x, &acid_1x])
    {
        let cons = out.final_consensus().unwrap_or(f64::NAN);
        table.row(&[
            (*name).into(),
            format!("{rate}"),
            format!("{:.4}", out.final_loss),
            format!("{cons:.4}"),
        ]);
    }
    // Dump the three loss/consensus curves for plotting the actual figure.
    let mut rec = crate::metrics::Recorder::new();
    for (label, out) in [
        ("baseline_1x", &baseline_1x),
        ("baseline_2x", &baseline_2x),
        ("acid_1x", &acid_1x),
    ] {
        let mut s = out.loss.clone();
        s.name = format!("loss/{label}");
        rec.series.push(s);
        if let Some(c) = &out.consensus {
            let mut c = c.clone();
            c.name = format!("consensus/{label}");
            rec.series.push(c);
        }
    }
    let csv = std::path::Path::new("results/fig1_curves.csv");
    if rec.write_csv(csv, 1000).is_ok() {
        println!("(fig1 curves -> {})", csv.display());
    }
    Ok((Fig1 { baseline_1x, baseline_2x, acid_1x }, vec![table]))
}

pub fn report(scale: Scale) -> crate::Result<Report> {
    let (fig, tables) = run(scale)?;
    let records = VARIANTS
        .iter()
        .zip([&fig.baseline_1x, &fig.baseline_2x, &fig.acid_1x])
        .map(|((name, _, rate), out)| {
            Record::new()
                .str("variant", *name)
                .f64("comm_rate", *rate)
                .f64("final_loss", out.final_loss)
                .opt_f64("final_consensus", out.final_consensus())
                .opt_f64("accuracy", out.accuracy)
        })
        .collect();
    let summary = Summary {
        final_loss: Some(fig.acid_1x.final_loss),
        final_consensus: fig.acid_1x.final_consensus(),
        accuracy: fig.acid_1x.accuracy,
    };
    Ok(Report { tables, records, summary })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn acid_matches_doubled_rate_ordering() {
        let (fig, tables) = run(Scale::Quick).unwrap();
        assert_eq!(tables.len(), 1);
        // The acceleration claim, in ordering form: A2CiD2@1 and
        // baseline@2 both beat baseline@1 on the ring.
        assert!(
            fig.acid_1x.final_loss < fig.baseline_1x.final_loss * 1.05,
            "acid {} vs baseline {}",
            fig.acid_1x.final_loss,
            fig.baseline_1x.final_loss
        );
        assert!(fig.baseline_2x.final_loss < fig.baseline_1x.final_loss * 1.05);
    }
}
