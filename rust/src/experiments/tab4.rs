//! Tab. 4 — CIFAR-like held-out accuracy: AR-SGD vs async baseline vs
//! A²CiD² across the three topologies and the n grid.
//!
//! Paper shape: all methods are close at small n; at n = 64 the ring
//! baseline drops hard (91.9 vs 92.8 AR) and A²CiD² recovers most of it
//! (93.08); the momentum never hurts on well-connected graphs.

use crate::config::{Method, Task};
use crate::graph::Topology;
use crate::metrics::Table;

use super::common::{base_config, over_seeds, Scale};

pub fn run(scale: Scale) -> crate::Result<Vec<Table>> {
    let mut cfg = base_config(scale);
    cfg.task = Task::CifarLike;
    cfg.comm_rate = 1.0;

    let grid = scale.n_grid();
    let mut header: Vec<String> = vec!["variant".into()];
    header.extend(grid.iter().map(|n| format!("n={n}")));
    let header_refs: Vec<&str> = header.iter().map(|s| s.as_str()).collect();
    let mut table = Table::new(
        "Tab.4 — CIFAR-like held-out accuracy (mean±std over seeds)",
        &header_refs,
    );

    let variants: Vec<(String, Topology, Method)> = vec![
        ("AR-SGD".into(), Topology::Complete, Method::AllReduce),
        ("complete / baseline".into(), Topology::Complete, Method::AsyncBaseline),
        ("exponential / baseline".into(), Topology::Exponential, Method::AsyncBaseline),
        ("exponential / A2CiD2".into(), Topology::Exponential, Method::Acid),
        ("ring / baseline".into(), Topology::Ring, Method::AsyncBaseline),
        ("ring / A2CiD2".into(), Topology::Ring, Method::Acid),
    ];
    for (name, topo, method) in variants {
        let mut cells = vec![name];
        for &n in &grid {
            super::common::set_workers(&mut cfg, n, scale);
            cfg.topology = topo.clone();
            cfg.method = method;
            let stats = over_seeds(scale, &cfg, |o| 100.0 * o.accuracy.unwrap_or(f64::NAN))?;
            cells.push(stats.pm(1));
        }
        table.row(&cells);
    }
    Ok(vec![table])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table_has_all_variants() {
        let tables = run(Scale::Quick).unwrap();
        assert_eq!(tables[0].rows.len(), 6);
        // Every accuracy cell parses as a number well above chance (10%).
        for row in &tables[0].rows {
            for cell in &row[1..] {
                let acc: f64 = cell.split('±').next().unwrap().parse().unwrap();
                assert!(acc > 30.0, "{}: {cell}", row[0]);
            }
        }
    }
}
