//! Tab. 4 — CIFAR-like held-out accuracy: AR-SGD vs async baseline vs
//! A²CiD² across the three topologies and the n grid.
//!
//! Paper shape: all methods are close at small n; at n = 64 the ring
//! baseline drops hard (91.9 vs 92.8 AR) and A²CiD² recovers most of it
//! (93.08); the momentum never hurts on well-connected graphs.

use crate::config::{Method, Task};
use crate::graph::Topology;
use crate::metrics::{Record, Stats, Table};

use super::common::{base_config, set_workers, variant_grid_cells, Scale};
use super::{Report, Summary};

fn variants() -> Vec<(String, Topology, Method)> {
    vec![
        ("AR-SGD".into(), Topology::Complete, Method::AllReduce),
        ("complete / baseline".into(), Topology::Complete, Method::AsyncBaseline),
        ("exponential / baseline".into(), Topology::Exponential, Method::AsyncBaseline),
        ("exponential / A2CiD2".into(), Topology::Exponential, Method::Acid),
        ("ring / baseline".into(), Topology::Ring, Method::AsyncBaseline),
        ("ring / A2CiD2".into(), Topology::Ring, Method::Acid),
    ]
}

/// Variant label → one accuracy cell per grid n.
type AccuracyRows = Vec<(String, Vec<Stats>)>;

/// Run the full (variant × n) grid; cells aggregate accuracy over the
/// scale's seeds. Returned in declaration order, variant-major.
fn accuracy_grid(scale: Scale) -> crate::Result<(Vec<usize>, AccuracyRows)> {
    let cfg = {
        let mut c = base_config(scale);
        c.task = Task::CifarLike;
        c.comm_rate = 1.0;
        c
    };
    let grid = scale.n_grid();
    let variants = variants();
    let cells = variant_grid_cells(
        &variants,
        &grid,
        &scale.seeds(),
        |(_, topo, method), n| {
            let mut c = cfg.clone();
            set_workers(&mut c, n, scale);
            c.topology = topo.clone();
            c.method = *method;
            c
        },
        |o| 100.0 * o.accuracy.unwrap_or(f64::NAN),
    )?;
    let rows = variants
        .into_iter()
        .zip(cells.chunks(grid.len()))
        .map(|((name, _, _), row)| (name, row.to_vec()))
        .collect();
    Ok((grid, rows))
}

fn tables_from(grid: &[usize], rows: &[(String, Vec<Stats>)]) -> Vec<Table> {
    let mut header: Vec<String> = vec!["variant".into()];
    header.extend(grid.iter().map(|n| format!("n={n}")));
    let header_refs: Vec<&str> = header.iter().map(|s| s.as_str()).collect();
    let mut table = Table::new(
        "Tab.4 — CIFAR-like held-out accuracy (mean±std over seeds)",
        &header_refs,
    );
    for (name, cells) in rows {
        let mut row = vec![name.clone()];
        row.extend(cells.iter().map(|s| s.pm(1)));
        table.row(&row);
    }
    vec![table]
}

pub fn run(scale: Scale) -> crate::Result<Vec<Table>> {
    let (grid, rows) = accuracy_grid(scale)?;
    Ok(tables_from(&grid, &rows))
}

pub fn report(scale: Scale) -> crate::Result<Report> {
    let (grid, rows) = accuracy_grid(scale)?;
    let mut records = Vec::new();
    for (name, cells) in &rows {
        for (&n, stats) in grid.iter().zip(cells) {
            records.push(
                Record::new()
                    .str("variant", name.clone())
                    .u64("n", n as u64)
                    .f64("accuracy", stats.mean)
                    .f64("accuracy_std", stats.std),
            );
        }
    }
    let summary = Summary {
        // Headline: ring / A2CiD2 at the largest n.
        accuracy: rows.last().and_then(|(_, cells)| cells.last()).map(|s| s.mean),
        ..Summary::default()
    };
    Ok(Report { tables: tables_from(&grid, &rows), records, summary })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table_has_all_variants() {
        let tables = run(Scale::Quick).unwrap();
        assert_eq!(tables[0].rows.len(), 6);
        // Every accuracy cell parses as a number well above chance (10%).
        for row in &tables[0].rows {
            for cell in &row[1..] {
                let acc: f64 = cell.split('±').next().unwrap().parse().unwrap();
                assert!(acc > 30.0, "{}: {cell}", row[0]);
            }
        }
    }
}
