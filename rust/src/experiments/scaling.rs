//! Massive-fleet scaling: hierarchical topologies under the multiplexed
//! engine, from 10³ to 10⁵ (10⁶ at full scale) virtual workers.
//!
//! The paper stops at n = 64 OS-threaded workers; the ROADMAP's
//! open question is whether A²CiD²'s χ₁-flattening survives to fleet
//! sizes where thread-per-worker is physically impossible. This
//! experiment answers it with the three scaling layers together:
//!
//! * **hierarchy** — `cluster_ring(k, m)`: k rings of m workers bridged
//!   by an exponential graph over cluster representatives. χ₁ is pinned
//!   by the *cluster* size (the rings dominate the spectral gap), so it
//!   stays flat in k while a flat ring of the same n degrades as n²
//!   (the `flat_ring_chi1` column, closed form);
//! * **sparse spectra** — (χ₁, χ₂) via the truncated Lanczos estimator
//!   ([`crate::linalg::lanczos`]) at O(edges) per iteration, the only
//!   way to get Eq. 2/3 quantities at 10⁵ nodes;
//! * **multiplexed execution** — the consensus-decay probe runs on
//!   [`MultiplexEngine`]: the exact virtual-time event stream, cut into
//!   worker-disjoint frames and fanned over a fixed pool, bit-identical
//!   to the serial scheduler at any pool width.
//!
//! Reported per cell: graph size, (χ₁, χ₂), the flat-ring closed form,
//! communications needed to shave 10% off the initial consensus
//! distance (`comms_to_target`, `null` if the event cap landed first),
//! wall ms, deterministic resident bytes per worker, and the process
//! peak RSS (`peak_rss_kb`, Linux only, informational). CI's
//! experiments-smoke job gates on the wall-ms and bytes-per-worker
//! columns of these rows.

use crate::config::NetworkPlan;
use crate::engine::{MultiplexEngine, Tick};
use crate::gossip::dynamics::comm_event;
use crate::gossip::{consensus_distance_sq, AcidParams, Mixer, WorkerState};
use crate::graph::{Graph, Topology};
use crate::metrics::{Record, Table};
use crate::rng::{standard_normal, Xoshiro256};

use super::common::Scale;
use super::{Report, Summary};

/// Consensus-squared target as a fraction of its initial value: 10% off.
/// Deliberately mild — decay time scales with χ of the *cluster*, so the
/// event budget stays near-linear in n across the whole grid.
pub const TARGET_CONSENSUS_FRAC: f64 = 0.9;

/// Event cap, per worker: a cell that has not hit the target after this
/// many communications per worker reports `comms_to_target = null`
/// instead of running away.
pub const MAX_COMMS_PER_WORKER: u64 = 80;

/// Parameter dimension of the decay probe. Small on purpose: the cell
/// cost is event-count dominated and memory must stay ~linear in n with
/// a small constant (10⁶ workers × 2 buffers at full scale).
pub const DIM: usize = 8;

/// One (clusters, ring-size) cell of the grid.
pub struct ScalingCell {
    pub clusters: usize,
    pub ring: usize,
    pub n: usize,
    pub edges: usize,
    pub chi1: f64,
    pub chi2: f64,
    /// χ₁ of a *flat* ring with the same n (closed form) — the
    /// no-hierarchy counterfactual the χ₁ column is read against.
    pub flat_ring_chi1: f64,
    /// Communication events until consensus² first dropped below
    /// [`TARGET_CONSENSUS_FRAC`] × initial; `None` if capped.
    pub comms_to_target: Option<u64>,
    pub wall_ms: u64,
    /// Deterministic resident footprint of one virtual worker's state
    /// (both parameter buffers plus the struct header).
    pub bytes_per_worker: u64,
    /// `VmHWM` of the process after the cell ran (Linux; `None`
    /// elsewhere). Process-wide, so informational — the deterministic
    /// per-worker column is what CI gates on.
    pub peak_rss_kb: Option<u64>,
}

impl ScalingCell {
    pub fn record(&self) -> Record {
        Record::new()
            .u64("n", self.n as u64)
            .u64("clusters", self.clusters as u64)
            .u64("ring", self.ring as u64)
            .u64("edges", self.edges as u64)
            .f64("chi1", self.chi1)
            .f64("chi2", self.chi2)
            .f64("flat_ring_chi1", self.flat_ring_chi1)
            // The χ₁(n) trend in one scalar: hierarchy ÷ flat-ring. ≪ 1
            // and shrinking with n; the conformance oracle pins it.
            .f64("chi1_vs_flat", self.chi1 / self.flat_ring_chi1)
            .opt_u64("comms_to_target", self.comms_to_target)
            .u64("wall_ms", self.wall_ms)
            .u64("bytes_per_worker", self.bytes_per_worker)
            .opt_u64("peak_rss_kb", self.peak_rss_kb)
    }
}

/// The (clusters, ring) grid per scale. Ring size is held at 100 in the
/// release grids so the χ₁ column is flat by construction and only the
/// bridge term can move it; unoptimized test builds shrink everything.
pub fn grid(scale: Scale) -> Vec<(usize, usize)> {
    match scale {
        Scale::Quick if cfg!(debug_assertions) => vec![(4, 25), (8, 25)],
        Scale::Quick => vec![(10, 100), (100, 100), (1_000, 100)],
        Scale::Full => vec![(10, 100), (100, 100), (1_000, 100), (10_000, 100)],
    }
}

/// `VmHWM` (peak resident set) of this process in KiB, Linux only.
pub fn peak_rss_kb() -> Option<u64> {
    #[cfg(target_os = "linux")]
    {
        let status = std::fs::read_to_string("/proc/self/status").ok()?;
        let line = status.lines().find(|l| l.starts_with("VmHWM:"))?;
        return line.split_whitespace().nth(1)?.parse().ok();
    }
    #[cfg(not(target_os = "linux"))]
    {
        None
    }
}

/// Consensus distance squared of the fleet synced to time `t` (lazy
/// momentum mixing), without cloning worker state: one `mix_into` pass
/// accumulating Σ‖x_i‖² and Σx_i in f64, then Σ‖x_i − x̄‖² =
/// Σ‖x_i‖² − n‖x̄‖². Worker-order serial, so the measurement is
/// deterministic regardless of pool width.
fn consensus_sq_at(workers: &[WorkerState], t: f64, mixer: &Mixer, scratch: &mut [f32]) -> f64 {
    let n = workers.len() as f64;
    let dim = scratch.len();
    let mut sum = vec![0.0f64; dim];
    let mut sumsq = 0.0f64;
    for w in workers {
        w.mix_into(t, mixer, scratch);
        for (s, &v) in sum.iter_mut().zip(scratch.iter()) {
            let v = v as f64;
            *s += v;
            sumsq += v * v;
        }
    }
    let mean_sq: f64 = sum.iter().map(|s| (s / n) * (s / n)).sum();
    (sumsq - n * mean_sq).max(0.0)
}

/// Run the consensus-decay probe for one cell on the multiplexed engine.
/// Returns the comm-event count at target (or `None` if capped).
fn decay_on_multiplex(
    plan: &NetworkPlan,
    params: &AcidParams,
    seed: u64,
) -> crate::Result<Option<u64>> {
    let n = plan.union.n;
    let mixer = Mixer::new(params.eta);
    let mut rng = Xoshiro256::seed_from_u64(seed);
    let mut workers: Vec<WorkerState> = (0..n)
        .map(|_| WorkerState::new((0..DIM).map(|_| standard_normal(&mut rng) as f32).collect()))
        .collect();
    let target = consensus_distance_sq(&workers) * TARGET_CONSENSUS_FRAC;
    let cap = MAX_COMMS_PER_WORKER * n as u64;
    let mut scratch = vec![0.0f32; DIM];
    let mut comms = 0u64;
    let mut check_at = 0.5f64;
    let mut eng = MultiplexEngine::new(plan, seed ^ 0xFEED);
    while let Some(frame) = eng.next_frame() {
        // A static plan records no changes; the probe asserts that
        // assumption rather than silently dropping churn.
        anyhow::ensure!(frame.changes.is_empty(), "decay probe expects a static plan");
        eng.execute(
            &mut workers,
            &frame.ticks,
            &|_worker, _t, _w: &mut WorkerState| {
                // Gradient rates are ~1e-12: no gradient fires within any
                // realistic cap. Nothing to do if one ever does.
            },
            &|t, a: &mut WorkerState, b: &mut WorkerState| {
                comm_event(a, b, t, params, &mixer);
            },
        );
        comms += frame
            .ticks
            .iter()
            .filter(|t| matches!(t, Tick::Comm { .. }))
            .count() as u64;
        let now = eng.now();
        if now >= check_at {
            check_at = now + 0.5;
            if consensus_sq_at(&workers, now, &mixer, &mut scratch) < target {
                return Ok(Some(comms));
            }
        }
        if comms >= cap {
            return Ok(None);
        }
    }
    Ok(None)
}

fn run_cell(clusters: usize, ring: usize, seed: u64) -> crate::Result<ScalingCell> {
    let t0 = std::time::Instant::now();
    let topology = Topology::ClusterRing { clusters, ring };
    let n = clusters * ring;
    let graph = Graph::build(&topology, n)?;
    let edges = graph.edges.len();
    // One spectrum estimate per cell: dense-exact at small n, truncated
    // Lanczos beyond (static_plan routes through `spectrum_auto`).
    let plan = NetworkPlan::static_plan(graph, 1.0, &vec![1e-12; n]);
    let params = AcidParams::from_spectrum(&plan.spectrum);
    let flat_ring_chi1 = Topology::Ring
        .closed_form_chis(n, 1.0)
        .map(|(chi1, _)| chi1)
        .unwrap_or(f64::NAN);
    let comms_to_target = decay_on_multiplex(&plan, &params, seed)?;
    let bytes_per_worker =
        (2 * DIM * std::mem::size_of::<f32>() + std::mem::size_of::<WorkerState>()) as u64;
    Ok(ScalingCell {
        clusters,
        ring,
        n,
        edges,
        chi1: plan.spectrum.chi1,
        chi2: plan.spectrum.chi2,
        flat_ring_chi1,
        comms_to_target,
        wall_ms: t0.elapsed().as_millis() as u64,
        bytes_per_worker,
        peak_rss_kb: peak_rss_kb(),
    })
}

pub fn run(scale: Scale) -> crate::Result<(Vec<ScalingCell>, Vec<Table>)> {
    // Cells run serially: the largest one dominates wall time anyway,
    // each spins its own multiplex pool, and memory peaks must not stack.
    let mut cells = Vec::new();
    for &(clusters, ring) in &grid(scale) {
        cells.push(run_cell(clusters, ring, 1013)?);
    }
    let mut table = Table::new(
        format!(
            "Scaling — cluster_ring(k, m) on the multiplexed engine; \
             comms to {:.0}% consensus², dim {DIM}",
            TARGET_CONSENSUS_FRAC * 100.0
        ),
        &[
            "n",
            "k×m",
            "edges",
            "chi1",
            "chi2",
            "flat-ring chi1",
            "#comm→target",
            "wall ms",
            "B/worker",
        ],
    );
    for c in &cells {
        table.row(&[
            c.n.to_string(),
            format!("{}×{}", c.clusters, c.ring),
            c.edges.to_string(),
            format!("{:.1}", c.chi1),
            format!("{:.1}", c.chi2),
            format!("{:.1}", c.flat_ring_chi1),
            c.comms_to_target.map_or("capped".into(), |v| v.to_string()),
            c.wall_ms.to_string(),
            c.bytes_per_worker.to_string(),
        ]);
    }
    Ok((cells, vec![table]))
}

pub fn report(scale: Scale) -> crate::Result<Report> {
    let (cells, tables) = run(scale)?;
    let records = cells.iter().map(ScalingCell::record).collect();
    Ok(Report { tables, records, summary: Summary::default() })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn hierarchy_flattens_chi1_against_the_flat_ring() {
        let (cells, tables) = run(Scale::Quick).unwrap();
        assert_eq!(cells.len(), grid(Scale::Quick).len());
        assert_eq!(tables.len(), 1);
        for c in &cells {
            assert_eq!(c.n, c.clusters * c.ring);
            assert!(c.edges >= c.n, "bridged rings have ≥ n edges");
            assert!(c.chi1.is_finite() && c.chi1 > 0.0);
            assert!(c.chi2.is_finite() && c.chi2 > 0.0);
            // The tentpole claim: the hierarchy's χ₁ beats the flat
            // ring's as soon as there is more than one cluster.
            assert!(
                c.chi1 < c.flat_ring_chi1,
                "cluster_ring({}, {}) chi1 {} vs flat ring {}",
                c.clusters,
                c.ring,
                c.chi1,
                c.flat_ring_chi1
            );
            assert!(c.bytes_per_worker >= (2 * DIM * 4) as u64);
            assert!(
                c.comms_to_target.is_some(),
                "small cells must reach the 10% target within the cap"
            );
        }
        // χ₁ is pinned by the cluster, not the fleet: growing k with m
        // fixed must not blow it up (same-m cells stay within 2×).
        for pair in cells.windows(2) {
            if pair[0].ring == pair[1].ring {
                assert!(pair[1].chi1 < pair[0].chi1 * 2.0 + 1.0);
            }
        }
    }

    #[test]
    fn cells_are_deterministic() {
        let (k, m) = grid(Scale::Quick)[0];
        let a = run_cell(k, m, 7).unwrap();
        let b = run_cell(k, m, 7).unwrap();
        assert_eq!(a.chi1.to_bits(), b.chi1.to_bits());
        assert_eq!(a.chi2.to_bits(), b.chi2.to_bits());
        assert_eq!(a.comms_to_target, b.comms_to_target);
        assert_eq!(a.edges, b.edges);
    }

    #[test]
    fn records_render_the_gated_columns() {
        let c = ScalingCell {
            clusters: 10,
            ring: 100,
            n: 1000,
            edges: 1017,
            chi1: 60.0,
            chi2: 25.0,
            flat_ring_chi1: 1013.0,
            comms_to_target: None,
            wall_ms: 12,
            bytes_per_worker: 120,
            peak_rss_kb: peak_rss_kb(),
        };
        let text = crate::metrics::render_records(&[c.record()]);
        assert!(text.contains("\"comms_to_target\": null"));
        assert!(text.contains("\"bytes_per_worker\": 120"));
        assert!(text.contains("\"wall_ms\": 12"));
        #[cfg(target_os = "linux")]
        assert!(text.contains("\"peak_rss_kb\": "));
    }
}
