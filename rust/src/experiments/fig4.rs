//! Fig. 4 — ring graph: training loss w/ and w/o A²CiD² as n grows.
//! The paper: the gap opens with n (χ₁ = Θ(n²) on the ring) and the
//! momentum recovers most of it.

use crate::config::{Method, Task};
use crate::graph::Topology;
use crate::metrics::Table;

use super::common::{base_config, train_once, Scale};

pub struct Fig4Row {
    pub n: usize,
    pub baseline_loss: f64,
    pub acid_loss: f64,
}

pub fn run(scale: Scale) -> crate::Result<(Vec<Fig4Row>, Vec<Table>)> {
    let mut cfg = base_config(scale);
    cfg.topology = Topology::Ring;
    cfg.task = Task::CifarLike;
    cfg.comm_rate = 1.0;

    let mut rows = Vec::new();
    let mut table = Table::new(
        "Fig.4 — ring graph, w/ vs w/o A2CiD2 (paper: momentum recovers the large-n gap)",
        &["n", "baseline loss", "A2CiD2 loss", "chi1", "sqrt(chi1*chi2)"],
    );
    for n in scale.n_grid() {
        super::common::set_workers(&mut cfg, n, scale);
        cfg.method = Method::AsyncBaseline;
        let base = train_once(&cfg)?;
        cfg.method = Method::Acid;
        let acid = train_once(&cfg)?;
        let (chi1, chi2) = acid.chis.unwrap();
        table.row(&[
            n.to_string(),
            format!("{:.4}", base.final_loss),
            format!("{:.4}", acid.final_loss),
            format!("{chi1:.1}"),
            format!("{:.1}", (chi1 * chi2).sqrt()),
        ]);
        rows.push(Fig4Row { n, baseline_loss: base.final_loss, acid_loss: acid.final_loss });
    }
    Ok((rows, vec![table]))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn acid_at_least_matches_baseline_at_large_n() {
        let (rows, _) = run(Scale::Quick).unwrap();
        let last = rows.last().unwrap();
        assert!(
            last.acid_loss <= last.baseline_loss * 1.1,
            "n={}: acid {} vs baseline {}",
            last.n,
            last.acid_loss,
            last.baseline_loss
        );
    }
}
