//! Fig. 4 — ring graph: training loss w/ and w/o A²CiD² as n grows.
//! The paper: the gap opens with n (χ₁ = Θ(n²) on the ring) and the
//! momentum recovers most of it.

use crate::config::{Method, Task};
use crate::graph::{Graph, Topology};
use crate::metrics::{Record, Stats, Table};

use super::common::{aggregate_config_seeds, base_config, GridRunner, Scale};
use super::{Report, Summary};

pub struct Fig4Row {
    pub n: usize,
    pub chi1: f64,
    pub chi_acc: f64,
    /// Final loss, mean ± std over the scale's seeds.
    pub baseline_loss: Stats,
    pub acid_loss: Stats,
}

pub fn run(scale: Scale) -> crate::Result<(Vec<Fig4Row>, Vec<Table>)> {
    let mut cfg = base_config(scale);
    cfg.topology = Topology::Ring;
    cfg.task = Task::CifarLike;
    cfg.comm_rate = 1.0;

    let grid = scale.n_grid();
    let seeds = scale.seeds();
    let rows = GridRunner::from_env().run(&grid, |&n| {
        let mut cfg = cfg.clone();
        super::common::set_workers(&mut cfg, n, scale);
        let loss_over_seeds = |method: Method| {
            let mut c = cfg.clone();
            c.method = method;
            aggregate_config_seeds(&seeds, &c, |o| o.final_loss)
        };
        let baseline_loss = loss_over_seeds(Method::AsyncBaseline)?;
        let acid_loss = loss_over_seeds(Method::Acid)?;
        let s = Graph::build(&Topology::Ring, n)?.spectrum(cfg.comm_rate);
        Ok(Fig4Row { n, chi1: s.chi1, chi_acc: s.chi_acc(), baseline_loss, acid_loss })
    })?;

    let mut table = Table::new(
        "Fig.4 — ring graph, w/ vs w/o A2CiD2 (paper: momentum recovers the large-n gap)",
        &["n", "baseline loss", "A2CiD2 loss", "chi1", "sqrt(chi1*chi2)"],
    );
    for row in &rows {
        table.row(&[
            row.n.to_string(),
            row.baseline_loss.pm(4),
            row.acid_loss.pm(4),
            format!("{:.1}", row.chi1),
            format!("{:.1}", row.chi_acc),
        ]);
    }
    Ok((rows, vec![table]))
}

pub fn report(scale: Scale) -> crate::Result<Report> {
    let (rows, tables) = run(scale)?;
    let records = rows
        .iter()
        .map(|r| {
            Record::new()
                .u64("n", r.n as u64)
                .f64("chi1", r.chi1)
                .f64("chi_acc", r.chi_acc)
                .f64("baseline_loss", r.baseline_loss.mean)
                .f64("baseline_loss_std", r.baseline_loss.std)
                .f64("acid_loss", r.acid_loss.mean)
                .f64("acid_loss_std", r.acid_loss.std)
        })
        .collect();
    let summary = Summary {
        final_loss: rows.last().map(|r| r.acid_loss.mean),
        ..Summary::default()
    };
    Ok(Report { tables, records, summary })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn acid_at_least_matches_baseline_at_large_n() {
        let (rows, _) = run(Scale::Quick).unwrap();
        let last = rows.last().unwrap();
        assert!(
            last.acid_loss.mean <= last.baseline_loss.mean * 1.1,
            "n={}: acid {} vs baseline {}",
            last.n,
            last.acid_loss.mean,
            last.baseline_loss.mean
        );
        // The chi columns come straight from the spectrum now; the ring's
        // accelerated factor must sit strictly below chi1 at the tail.
        assert!(last.chi_acc < last.chi1);
    }
}
