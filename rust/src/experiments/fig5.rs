//! Fig. 5 — the harder (ImageNet-like) task on the ring:
//! (a) training loss with A²CiD² across n;
//! (b) consensus distance: A²CiD² @ rate 1 vs baseline @ rate 2 vs
//!     baseline @ rate 1 — the "virtual doubling" seen through ‖πx‖.

use crate::config::{Method, Task};
use crate::graph::Topology;
use crate::metrics::{Series, Table};

use super::common::{base_config, run_grid, GridPoint, Scale};
use super::{Report, Summary};

pub struct Fig5b {
    pub baseline_1x: Series,
    pub baseline_2x: Series,
    pub acid_1x: Series,
}

pub fn run(scale: Scale) -> crate::Result<(Fig5b, Vec<Table>)> {
    let mut cfg = base_config(scale);
    cfg.topology = Topology::Ring;
    cfg.task = Task::ImagenetLike;
    cfg.comm_rate = 1.0;

    // (a) loss across n, A²CiD² and baseline — one flat declared grid.
    let grid = scale.n_grid();
    let mut points = Vec::with_capacity(grid.len() * 2);
    for &n in &grid {
        for method in [Method::Acid, Method::AsyncBaseline] {
            let mut c = cfg.clone();
            super::common::set_workers(&mut c, n, scale);
            c.method = method;
            points.push(GridPoint::new(c, cfg.seed));
        }
    }
    let outs = run_grid(&points)?;
    let mut ta = Table::new(
        "Fig.5a — ImageNet-like ring, A2CiD2 (paper: loss vs n)",
        &["n", "A2CiD2 loss", "baseline loss"],
    );
    for (&n, pair) in grid.iter().zip(outs.chunks(2)) {
        ta.row(&[
            n.to_string(),
            format!("{:.4}", pair[0].final_loss),
            format!("{:.4}", pair[1].final_loss),
        ]);
    }

    // (b) consensus traces at the largest n.
    super::common::set_workers(&mut cfg, scale.n_max(), scale);
    let variants = [
        (Method::AsyncBaseline, 1.0),
        (Method::AsyncBaseline, 2.0),
        (Method::Acid, 1.0),
    ];
    let points: Vec<GridPoint> = variants
        .iter()
        .map(|&(method, rate)| {
            let mut c = cfg.clone();
            c.method = method;
            c.comm_rate = rate;
            GridPoint::new(c, cfg.seed)
        })
        .collect();
    let mut traces = run_grid(&points)?
        .into_iter()
        .map(|o| o.consensus.unwrap_or_default());
    let (baseline_1x, baseline_2x, acid_1x) = (
        traces.next().expect("baseline@1"),
        traces.next().expect("baseline@2"),
        traces.next().expect("acid@1"),
    );

    let mut tb = Table::new(
        format!(
            "Fig.5b — consensus distance, ring n={} (paper: A2CiD2@1 ≈ baseline@2)",
            cfg.n_workers
        ),
        &["variant", "com/grad", "mean consensus (2nd half)"],
    );
    for (name, rate, s) in [
        ("async baseline", 1.0, &baseline_1x),
        ("async baseline", 2.0, &baseline_2x),
        ("A2CiD2", 1.0, &acid_1x),
    ] {
        tb.row(&[name.into(), format!("{rate}"), format!("{:.4}", s.tail_mean(0.5))]);
    }
    // Dump the consensus traces for plotting Fig. 5b.
    let mut rec = crate::metrics::Recorder::new();
    for (label, s) in [
        ("baseline_1x", &baseline_1x),
        ("baseline_2x", &baseline_2x),
        ("acid_1x", &acid_1x),
    ] {
        let mut s = s.clone();
        s.name = format!("consensus/{label}");
        rec.series.push(s);
    }
    let csv = std::path::Path::new("results/fig5b_consensus.csv");
    if rec.write_csv(csv, 1000).is_ok() {
        println!("(fig5b curves -> {})", csv.display());
    }
    Ok((Fig5b { baseline_1x, baseline_2x, acid_1x }, vec![ta, tb]))
}

pub fn report(scale: Scale) -> crate::Result<Report> {
    let (fig, tables) = run(scale)?;
    let summary = Summary {
        final_consensus: Some(fig.acid_1x.tail_mean(0.5)),
        ..Summary::default()
    };
    Ok(Report::from_tables(tables).with_summary(summary))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn acid_consensus_beats_baseline_at_rate_1() {
        let (fig, tables) = run(Scale::Quick).unwrap();
        assert_eq!(tables.len(), 2);
        // The headline mechanism: the momentum shrinks consensus distance
        // at the same communication budget.
        let base = fig.baseline_1x.tail_mean(0.5);
        let acid = fig.acid_1x.tail_mean(0.5);
        assert!(
            acid < base * 1.05,
            "consensus: acid {acid} vs baseline {base}"
        );
    }
}
