//! Head-to-head algorithm zoo: every [`crate::config::Algorithm`] on the
//! same seeded workloads, one row per (unit, arm).
//!
//! Three units, fixed row order (the oracle indexes into it):
//!
//! * **race** (rows 0–3) — a pure consensus race on the ring-16: random
//!   initial parameters, zero gradients, communications at rate 1 per
//!   edge. Reported is the number of APPLIED pairings when the consensus
//!   distance first drops below 1% of its initial value. All
//!   asynchronous arms replay the SAME seeded Poisson event stream (the
//!   [`crate::engine::UpdateRule`] contract: rules skip proposals, they
//!   never reschedule them), so the arms differ only in their update
//!   rule. The all-reduce arm is the synchronous yardstick: one exact
//!   averaging round — `n − 1` pairwise messages along a reduce tree —
//!   ends the race with zero consensus gap.
//! * **ring** (rows 4–7) — logistic training on the static ring,
//!   all four arms (`cfg.algorithm` selects the rule); the AD-PSGD arm
//!   pins the shared target loss (a fixed fraction of its first recorded
//!   loss), and each asynchronous arm reports the communication count
//!   when it first reaches that target.
//! * **churn** (rows 8–10) — the same training task on the sweep's
//!   hardest scenario (mid-run topology switch + dropout + worker
//!   churn), asynchronous arms only, selected via the scenario string's
//!   `algo=` key.
//!
//! Arm order within every unit is `adpsgd, a2cid2, localsgd:4[,
//! allreduce]` — AD-PSGD first so it pins targets, A²CiD² second so the
//! checked-in ratio check (`rows.1.comms_to_target /
//! rows.0.comms_to_target` in `rust/oracle/paper.toml`) reads
//! "accelerated over baseline". The registry entry maintains
//! `BENCH_compare.json`.

use std::sync::Arc;

use crate::config::{Algorithm, ExperimentConfig, Method, Scenario, Task};
use crate::data::{GaussianMixture, Sharding};
use crate::engine::DynamicsCore;
use crate::gossip::{consensus_distance_sq, WorkerState};
use crate::graph::{Graph, Topology};
use crate::metrics::{Record, Table};
use crate::model::Logistic;
use crate::optim::{LrSchedule, Sgd};
use crate::rng::{standard_normal, Xoshiro256};
use crate::simulator::{run_allreduce, run_simulation, ArTimingConfig, EventKind, EventQueue};
use crate::util::two_mut;

use super::common::{comms_at, GridRunner, Scale};
use super::sweep::TARGET_LOSS_FRAC;
use super::{Report, Summary};

/// Race unit size (fixed across scales — the oracle's ratio and the
/// `n − 1` all-reduce row are pinned to the ring-16 spectrum).
pub const RACE_N: usize = 16;

/// Race target: consensus down to this fraction of its initial value.
pub const RACE_TARGET_FRAC: f64 = 1e-2;

/// The zoo, in row order: AD-PSGD pins targets, A²CiD² sits at index 1
/// for the checked-in ratio, then the paced and synchronous baselines.
pub fn arms() -> Vec<Algorithm> {
    vec![
        Algorithm::AdPsgd,
        Algorithm::A2cid2,
        Algorithm::LocalSgd { h: 4 },
        Algorithm::AllReduce,
    ]
}

/// One (unit, arm) row of `BENCH_compare.json`.
pub struct CompareRow {
    /// `race`, `ring`, or `churn`.
    pub unit: &'static str,
    /// Canonical algorithm string (`Algorithm`'s `Display`).
    pub algo: String,
    /// Applied communications when the unit's target was first reached;
    /// `None` if never (or not applicable — all-reduce training rows
    /// have no pairwise communications).
    pub comms_to_target: Option<u64>,
    /// Final training loss (`None` for the gradient-free race rows).
    pub final_loss: Option<f64>,
    pub final_consensus: f64,
    /// Applied communications over the whole run (for the race: up to
    /// the moment the target was hit).
    pub n_comms: u64,
    /// Wall time spent on this arm — CI gates regressions per
    /// (unit, algo) cell, mirroring the `scaling` per-cell gate.
    pub wall_ms: u64,
}

impl CompareRow {
    pub fn record(&self) -> Record {
        Record::new()
            .str("unit", self.unit)
            .str("algo", self.algo.clone())
            .opt_u64("comms_to_target", self.comms_to_target)
            .opt_f64("final_loss", self.final_loss)
            .f64("final_consensus", self.final_consensus)
            .u64("n_comms", self.n_comms)
            .u64("wall_ms", self.wall_ms)
    }
}

/// The consensus race for one asynchronous arm: applied pairings until
/// the consensus distance first measures below the target fraction.
/// Gradient clocks fire at rate 1 per worker with ZERO gradients — they
/// tick the per-worker step counters the local-SGD gate paces on without
/// moving any parameters, so every arm runs the same contraction
/// problem on the same event stream.
fn consensus_race(algo: Algorithm, seed: u64) -> crate::Result<CompareRow> {
    let started = std::time::Instant::now();
    let (n, dim) = (RACE_N, 32);
    let graph = Graph::build(&Topology::Ring, n)?;
    let rates = graph.edge_rates(1.0);
    let spectrum = graph.spectrum_with_rates(&rates);
    let core = DynamicsCore::for_algorithm(algo, &spectrum, LrSchedule::Constant { lr: 0.0 })?;
    let mut rng = Xoshiro256::seed_from_u64(seed);
    let mut workers: Vec<WorkerState> = (0..n)
        .map(|_| WorkerState::new((0..dim).map(|_| standard_normal(&mut rng) as f32).collect()))
        .collect();
    let target = consensus_distance_sq(&workers) * RACE_TARGET_FRAC;
    let mut optims: Vec<Sgd> = (0..n).map(|_| Sgd::new(0.0)).collect();
    let zero = vec![0.0f32; dim];
    let mut queue = EventQueue::new(&vec![1.0; n], &rates, seed ^ 0xFEED);
    let horizon = 200.0 * n as f64;
    let mut applied = 0u64;
    let mut comms_to_target = None;
    let mut check_at = 0.25f64;
    let mut last_consensus = f64::INFINITY;
    while let Some(ev) = queue.next(horizon) {
        match ev.kind {
            EventKind::Grad { worker } => {
                core.grad_event(&mut workers[worker], ev.t, &mut optims[worker], &zero);
            }
            EventKind::Comm { edge } => {
                let (i, j) = graph.edges[edge];
                let (a, b) = two_mut(&mut workers, i, j);
                if core.comm_event(a, b, ev.t) {
                    applied += 1;
                }
            }
        }
        if ev.t >= check_at {
            check_at = ev.t + 0.25;
            // Sync a snapshot to a common time before measuring (lazy
            // mixing), leaving the live states untouched.
            let mut snap = workers.clone();
            core.sync_all(&mut snap, ev.t);
            last_consensus = consensus_distance_sq(&snap);
            if last_consensus < target {
                comms_to_target = Some(applied);
                break;
            }
        }
    }
    Ok(CompareRow {
        unit: "race",
        algo: algo.to_string(),
        comms_to_target,
        final_loss: None,
        final_consensus: last_consensus,
        n_comms: applied,
        wall_ms: started.elapsed().as_millis() as u64,
    })
}

fn race_unit() -> crate::Result<Vec<CompareRow>> {
    arms()
        .into_iter()
        .map(|algo| {
            if algo == Algorithm::AllReduce {
                // One synchronous exact-averaging round ends the race:
                // n − 1 pairwise messages along a reduce tree, zero gap.
                Ok(CompareRow {
                    unit: "race",
                    algo: algo.to_string(),
                    comms_to_target: Some(RACE_N as u64 - 1),
                    final_loss: None,
                    final_consensus: 0.0,
                    n_comms: RACE_N as u64 - 1,
                    wall_ms: 0,
                })
            } else {
                consensus_race(algo, 7)
            }
        })
        .collect()
}

fn train_base(scale: Scale) -> ExperimentConfig {
    let steps = match scale {
        Scale::Quick if cfg!(debug_assertions) => 80,
        Scale::Quick => 250,
        Scale::Full => 700,
    };
    ExperimentConfig {
        n_workers: 8,
        topology: Topology::Ring,
        method: Method::AsyncBaseline,
        task: Task::CifarLike,
        comm_rate: 1.0,
        batch_size: 8,
        base_lr: 0.02,
        momentum: 0.0,
        weight_decay: 0.0,
        steps_per_worker: steps,
        sharding: Sharding::FullShuffled,
        dataset_size: 512,
        seed: 17,
        compute_jitter: 0.1,
        scenario: None,
        algorithm: None,
    }
}

/// The churn-unit scenario for one arm: the sweep's hardest cell
/// (mid-run ring→exponential switch, dropout window, 25% leave/re-join)
/// with the arm's update rule selected via the scenario grammar itself.
pub fn churn_scenario(algo: Algorithm) -> String {
    format!(
        "ring@0,exponential@0.5;drop=0.2:0.25:0.75:7;leave=0.25:0.3:1;join=0.25:0.8;algo={algo}"
    )
}

/// One training unit: every arm on the shared seed, AD-PSGD first to pin
/// the target loss (`TARGET_LOSS_FRAC` of its first recorded loss).
fn train_unit(unit: &'static str, scale: Scale) -> crate::Result<Vec<CompareRow>> {
    let base = train_base(scale);
    let arms: Vec<Algorithm> = if unit == "ring" {
        arms()
    } else {
        // Scenarios require an asynchronous rule (config::validate
        // rejects allreduce + scenario), so the churn unit runs three.
        arms().into_iter().filter(|a| *a != Algorithm::AllReduce).collect()
    };
    let ds = Arc::new(GaussianMixture::cifar_like().sample(base.dataset_size, 5));
    let shards = base.sharding.assign(&ds, base.n_workers, base.seed);
    let model = Arc::new(Logistic::new(ds, 0.0));
    let mut rows = Vec::with_capacity(arms.len());
    let mut target = f64::NAN;
    for algo in arms {
        let started = std::time::Instant::now();
        let mut cfg = base.clone();
        if unit == "ring" {
            cfg.algorithm = Some(algo);
        } else {
            cfg.scenario = Some(Scenario::parse(&churn_scenario(algo))?);
        }
        let cfg = cfg.validate()?;
        if algo == Algorithm::AllReduce {
            let res = run_allreduce(&cfg, model.clone(), &shards, &ArTimingConfig::default())?;
            rows.push(CompareRow {
                unit,
                algo: algo.to_string(),
                // Synchronous rounds, not pairwise gossip: no
                // comms-to-target under this unit's cost model.
                comms_to_target: None,
                final_loss: Some(res.final_loss()),
                final_consensus: 0.0,
                n_comms: 0,
                wall_ms: started.elapsed().as_millis() as u64,
            });
            continue;
        }
        let res = run_simulation(&cfg, model.clone(), &shards)?;
        if target.is_nan() {
            let first = res
                .recorder
                .get("train_loss")
                .and_then(|s| s.points.first().copied())
                .map(|(_, v)| v)
                .unwrap_or(f64::NAN);
            target = TARGET_LOSS_FRAC * first;
        }
        let comms = res
            .recorder
            .get("train_loss")
            .and_then(|s| s.first_time_below(target))
            .and_then(|t| comms_at(&res.recorder, t));
        rows.push(CompareRow {
            unit,
            algo: algo.to_string(),
            comms_to_target: comms,
            final_loss: Some(res.final_loss()),
            final_consensus: res.final_consensus(),
            n_comms: res.n_comms,
            wall_ms: started.elapsed().as_millis() as u64,
        });
    }
    Ok(rows)
}

pub fn run(scale: Scale) -> crate::Result<(Vec<CompareRow>, Vec<Table>)> {
    let mut rows = race_unit()?;
    let units = ["ring", "churn"];
    let trained = GridRunner::from_env().run(&units, |unit| train_unit(*unit, scale))?;
    for unit_rows in trained {
        rows.extend(unit_rows);
    }
    let mut table = Table::new(
        format!(
            "Algorithm zoo head-to-head — race (ring-{RACE_N}, to {:.0}% consensus) \
             + training (ring / churn scenario, target {:.0}% of first loss)",
            100.0 * RACE_TARGET_FRAC,
            100.0 * TARGET_LOSS_FRAC
        ),
        &["unit", "algo", "#comm→target", "final loss", "consensus", "#comms"],
    );
    for r in &rows {
        table.row(&[
            r.unit.to_string(),
            r.algo.clone(),
            r.comms_to_target.map_or("never".to_string(), |c| c.to_string()),
            r.final_loss.map_or("-".to_string(), |l| format!("{l:.4}")),
            format!("{:.4}", r.final_consensus),
            r.n_comms.to_string(),
        ]);
    }
    Ok((rows, vec![table]))
}

pub fn report(scale: Scale) -> crate::Result<Report> {
    let (rows, tables) = run(scale)?;
    let records = rows.iter().map(CompareRow::record).collect();
    let summary = Summary {
        final_loss: rows.last().and_then(|r| r.final_loss),
        final_consensus: rows.last().map(|r| r.final_consensus),
        ..Summary::default()
    };
    Ok(Report { tables, records, summary })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zoo_rows_cover_every_arm_and_a2cid2_wins_the_race() {
        let (rows, tables) = run(Scale::Quick).unwrap();
        assert_eq!(rows.len(), 11);
        assert_eq!(tables.len(), 1);
        let units: Vec<&str> = rows.iter().map(|r| r.unit).collect();
        assert_eq!(units[..4], ["race"; 4]);
        assert_eq!(units[4..8], ["ring"; 4]);
        assert_eq!(units[8..], ["churn"; 3]);
        for chunk in [&rows[..4], &rows[4..8]] {
            let algos: Vec<&str> = chunk.iter().map(|r| r.algo.as_str()).collect();
            assert_eq!(algos, ["adpsgd", "a2cid2", "localsgd:4", "allreduce"]);
        }
        // The paper's headline as a race: the accelerated dynamic needs
        // fewer pairings than plain averaging to reach 1% consensus on
        // the ring — the same claim `a2cid2 verify compare` checks
        // through the oracle's ratio row.
        let adpsgd = rows[0].comms_to_target.expect("adpsgd reaches the race target");
        let a2cid2 = rows[1].comms_to_target.expect("a2cid2 reaches the race target");
        assert!(a2cid2 < adpsgd, "a2cid2 {a2cid2} vs adpsgd {adpsgd} applied comms");
        // Paced local SGD still converges; it applies a subset of the
        // shared stream's proposals.
        assert!(rows[2].comms_to_target.is_some(), "localsgd reaches the race target");
        assert_eq!(rows[3].comms_to_target, Some(RACE_N as u64 - 1), "AR = n−1 messages");
        assert_eq!(rows[3].final_consensus, 0.0);
        // Training rows: finite losses everywhere; async rows also carry
        // consensus and communication counts.
        for r in &rows[4..] {
            let loss = r.final_loss.expect("training rows have a loss");
            assert!(loss.is_finite(), "{}/{}", r.unit, r.algo);
            if r.algo != "allreduce" {
                assert!(r.final_consensus.is_finite());
                assert!(r.n_comms > 0, "{}/{}", r.unit, r.algo);
            }
        }
        // The churn arms run the scenario-selected rules.
        assert_eq!(rows[8].algo, "adpsgd");
        assert_eq!(rows[9].algo, "a2cid2");
        assert_eq!(rows[10].algo, "localsgd:4");
    }

    #[test]
    fn churn_scenarios_round_trip_their_algorithm() {
        for algo in arms() {
            if algo == Algorithm::AllReduce {
                continue;
            }
            let parsed = Scenario::parse(&churn_scenario(algo)).unwrap();
            assert_eq!(parsed.algo, Some(algo));
            assert_eq!(parsed.churn.len(), 2);
        }
    }

    #[test]
    fn json_rows_render() {
        let r = CompareRow {
            unit: "race",
            algo: "localsgd:4".to_string(),
            comms_to_target: None,
            final_loss: None,
            final_consensus: 0.5,
            n_comms: 42,
            wall_ms: 3,
        };
        let text = crate::metrics::render_records(&[r.record()]);
        assert!(text.contains("\"unit\": \"race\""));
        assert!(text.contains("\"algo\": \"localsgd:4\""));
        assert!(text.contains("\"comms_to_target\": null"));
        assert!(text.contains("\"final_loss\": null"));
        assert!(text.trim_start().starts_with('['));
    }
}
