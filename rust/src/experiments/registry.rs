//! The experiment registry: one declarative entry per paper table/figure,
//! resolved by id everywhere an experiment can be launched.
//!
//! Every module in `experiments/` registers exactly once (pinned by a
//! test against the module list); the CLI (`a2cid2 experiment all
//! [--filter SUBSTR] [--json PATH]`), the `bench_main!` targets, and the
//! tests all resolve through [`find`]/[`all`] instead of hand-written
//! match arms. A run returns a [`Report`] — the human tables plus a
//! typed, serde-free JSON [`Record`] set — and `experiment all --json`
//! consolidates one row per experiment (id, scale, wall ms, final
//! loss/consensus/accuracy where applicable, and the full row set) into
//! `BENCH_experiments.json` via atomic writes.

use std::path::Path;
use std::sync::OnceLock;
use std::time::Instant;

use crate::metrics::{render_records, Record, Table};
use crate::runtime::artifacts::write_atomic;

use super::common::Scale;

/// What one experiment run hands back: the printable tables plus the
/// machine-readable record set (and the headline scalars, where the
/// workload has them).
pub struct Report {
    pub tables: Vec<Table>,
    /// Typed rows for the JSON artifacts. Experiments with natural row
    /// structs emit them directly; the rest bridge from their tables.
    pub records: Vec<Record>,
    pub summary: Summary,
}

/// Headline scalars of a run, `None` where the workload has no such
/// quantity (e.g. spectra-only experiments have no loss).
#[derive(Clone, Copy, Debug, Default)]
pub struct Summary {
    pub final_loss: Option<f64>,
    pub final_consensus: Option<f64>,
    pub accuracy: Option<f64>,
}

impl Report {
    /// Build a report whose records are bridged from the tables (the
    /// default for experiments without hand-written row types).
    pub fn from_tables(tables: Vec<Table>) -> Report {
        let records = tables.iter().flat_map(Table::to_records).collect();
        Report { tables, records, summary: Summary::default() }
    }

    pub fn with_summary(mut self, summary: Summary) -> Report {
        self.summary = summary;
        self
    }
}

/// One registered experiment. Implementations are the unit structs the
/// `register!` macro generates — `id()` always equals the module name,
/// so the registry, the CLI, and `bench_main!` share one namespace.
pub trait Experiment: Sync {
    fn id(&self) -> &'static str;
    /// Which paper item this reproduces (`Fig. 1` … `Tab. 6`), or
    /// `beyond` for the drivers that go past the paper's grid.
    fn paper_item(&self) -> &'static str;
    /// One-line description, mirrored verbatim in the `experiments`
    /// module doc table (pinned by `doc_table_matches_registry`).
    fn what(&self) -> &'static str;
    /// Standalone machine-readable artifact this experiment maintains
    /// (written next to the consolidated one on every registry run).
    fn artifact(&self) -> Option<&'static str> {
        None
    }
    fn run(&self, scale: Scale) -> crate::Result<Report>;
}

macro_rules! register {
    ($ty:ident, $module:ident, $paper:literal, $what:literal
     $(, artifact = $art:literal)?) => {
        struct $ty;
        impl Experiment for $ty {
            fn id(&self) -> &'static str {
                stringify!($module)
            }
            fn paper_item(&self) -> &'static str {
                $paper
            }
            fn what(&self) -> &'static str {
                $what
            }
            $(fn artifact(&self) -> Option<&'static str> {
                Some($art)
            })?
            fn run(&self, scale: Scale) -> crate::Result<Report> {
                super::$module::report(scale)
            }
        }
    };
}

register!(Fig1, fig1, "Fig. 1", "A²CiD² ≈ doubling the comm rate (ring, large n)");
register!(Fig2, fig2, "Fig. 2", "sync vs async worker timelines / idle time");
register!(Fig3, fig3, "Fig. 3", "complete graph: loss degrades with n; rate closes the gap");
register!(Fig4, fig4, "Fig. 4", "ring: w/ vs w/o A²CiD² across n");
register!(Fig5, fig5, "Fig. 5", "harder task: loss + consensus, A²CiD² vs 2× rate");
register!(Fig6, fig6, "Fig. 6", "topologies and their (χ₁, χ₂)");
register!(Fig7, fig7, "Fig. 7", "pairing heat-map ≈ uniform neighbor selection");
register!(Tab1, tab1, "Tab. 1", "time-to-ε scaling: χ₁ (baseline) vs √(χ₁χ₂) (A²CiD²)");
register!(Tab2, tab2, "Tab. 2", "#comms per unit time: star/ring/complete");
register!(Tab3, tab3, "Tab. 3", "training times vs n, ours vs AR-SGD");
register!(Tab4, tab4, "Tab. 4", "CIFAR-like accuracy across 3 graphs × n");
register!(Tab5, tab5, "Tab. 5", "ImageNet-like accuracy on the ring, rates 1 & 2");
register!(Tab6, tab6, "Tab. 6", "wall time + #∇ slowest/fastest worker");
register!(Ablation, ablation, "beyond", "momentum-rate η sweep around the theory's η*");
register!(
    Scaling,
    scaling,
    "beyond",
    "massive fleets: cluster_ring(k,m) χ₁ vs flat ring, multiplexed to 10⁵+"
);
register!(ScenarioExp, scenario, "beyond", "A²CiD² across a mid-run topology switch + dropout");
register!(
    Sweep,
    sweep,
    "beyond",
    "dropout × switch × churn × adaptive grid",
    artifact = "BENCH_sweep.json"
);
register!(
    Compare,
    compare,
    "beyond",
    "algorithm zoo head-to-head: consensus race + training, comms-to-target per arm",
    artifact = "BENCH_compare.json"
);

/// Every registered experiment, in `experiment all` execution order.
pub fn all() -> &'static [&'static dyn Experiment] {
    static REGISTRY: &[&dyn Experiment] = &[
        &Fig1, &Fig2, &Fig3, &Fig4, &Fig5, &Fig6, &Fig7, &Tab1, &Tab2, &Tab3, &Tab4, &Tab5,
        &Tab6, &Ablation, &Scaling, &ScenarioExp, &Sweep, &Compare,
    ];
    REGISTRY
}

/// Resolve an experiment by id (the CLI resolver).
pub fn find(id: &str) -> Option<&'static dyn Experiment> {
    all().iter().copied().find(|e| e.id() == id)
}

static SCALE: OnceLock<Scale> = OnceLock::new();

/// Pin the process-wide scale before anything resolves it (the CLI's
/// `--full` flag). Fails if [`scale`] already ran.
pub fn force_scale(s: Scale) -> Result<(), Scale> {
    SCALE.set(s)
}

/// THE `Scale::from_env` call site. Every entry point — the CLI and each
/// `bench_main!` target — resolves through this once-per-process cell,
/// so `A2CID2_BENCH_FULL` is consulted exactly once and cannot
/// half-apply when one experiment invokes another mid-run (as `sweep`
/// does through its per-point runs).
pub fn scale() -> Scale {
    *SCALE.get_or_init(Scale::from_env)
}

fn scale_name(scale: Scale) -> &'static str {
    match scale {
        Scale::Quick => "quick",
        Scale::Full => "full",
    }
}

/// Run one experiment, print its tables, maintain its standalone
/// artifact, and return its consolidated-artifact row — the same row
/// `experiment all --json` archives and `a2cid2 verify` diffs against
/// the conformance oracle (`testing::oracle`).
pub fn run_record(exp: &dyn Experiment, scale: Scale) -> crate::Result<Record> {
    let t0 = Instant::now();
    let report = exp.run(scale)?;
    let wall_ms = t0.elapsed().as_millis() as u64;
    for table in &report.tables {
        table.print();
    }
    if let Some(artifact) = exp.artifact() {
        let path = Path::new(artifact);
        write_atomic(path, render_records(&report.records).as_bytes())?;
        println!("wrote {} ({} rows)", path.display(), report.records.len());
    }
    Ok(Record::new()
        .str("id", exp.id())
        .str("paper_item", exp.paper_item())
        .str("scale", scale_name(scale))
        .u64("wall_ms", wall_ms)
        .opt_f64("final_loss", report.summary.final_loss)
        .opt_f64("final_consensus", report.summary.final_consensus)
        .opt_f64("accuracy", report.summary.accuracy)
        .u64("n_rows", report.records.len() as u64)
        .records("rows", report.records))
}

/// Resolve `id` (or `all`, optionally narrowed by `--filter SUBSTR`) to
/// the experiments to run, in registry order — shared by `experiment`
/// and `verify`.
pub fn select(id: &str, filter: Option<&str>) -> crate::Result<Vec<&'static dyn Experiment>> {
    let selected: Vec<&dyn Experiment> = if id == "all" {
        all()
            .iter()
            .copied()
            .filter(|e| filter.is_none_or(|f| e.id().contains(f)))
            .collect()
    } else {
        anyhow::ensure!(filter.is_none(), "--filter only applies to the 'all' selector");
        vec![find(id).ok_or_else(|| {
            anyhow::anyhow!("unknown experiment '{id}' (have: {}, all)", known_ids())
        })?]
    };
    anyhow::ensure!(
        !selected.is_empty(),
        "--filter '{}' matches no experiment (have: {})",
        filter.unwrap_or_default(),
        known_ids()
    );
    Ok(selected)
}

/// The `a2cid2 experiment` subcommand: resolve `id` (or `all`, optionally
/// narrowed by `--filter SUBSTR`) through the registry, run each
/// experiment at `scale`, and — with `--json PATH` — write the
/// consolidated artifact (one row per experiment) atomically.
pub fn run_cli(
    id: &str,
    filter: Option<&str>,
    json: Option<&Path>,
    scale: Scale,
) -> crate::Result<()> {
    let selected = select(id, filter)?;
    let mut rows = Vec::with_capacity(selected.len());
    let mut outcome = Ok(());
    for exp in selected {
        println!("=== {} ===", exp.id());
        match run_record(exp, scale) {
            Ok(row) => rows.push(row),
            Err(e) => {
                // Flush the completed rows below before surfacing the
                // failure — hours of finished experiments should not
                // vanish because a later one broke.
                outcome = Err(anyhow::anyhow!("experiment '{}': {e:#}", exp.id()));
                break;
            }
        }
    }
    if let Some(path) = json {
        write_atomic(path, render_records(&rows).as_bytes())?;
        println!(
            "wrote {} ({} experiment rows{})",
            path.display(),
            rows.len(),
            if outcome.is_err() { ", PARTIAL — a later experiment failed" } else { "" }
        );
    }
    outcome
}

/// Comma-joined registered ids — error messages and the CLI `--help`
/// text (regenerated from the registry, never hand-listed) share it.
pub fn known_ids() -> String {
    all().iter().map(|e| e.id()).collect::<Vec<_>>().join(", ")
}

/// Body of every `bench_main!` target: resolve the experiment through
/// the registry, run it at the process-wide scale, print, and time it.
pub fn bench_entry(id: &str) {
    let exp = find(id).unwrap_or_else(|| {
        panic!("'{id}' is not a registered experiment (have: {})", known_ids())
    });
    let scale = scale();
    let t0 = Instant::now();
    run_record(exp, scale).unwrap_or_else(|e| panic!("[{id}] failed: {e:#}"));
    println!("[{id}] completed in {:.1}s at {scale:?} scale", t0.elapsed().as_secs_f64());
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::collections::BTreeSet;

    fn normalize(s: &str) -> String {
        s.split_whitespace().collect::<Vec<_>>().join(" ")
    }

    /// Every `pub mod` in `experiments/` (besides the infrastructure
    /// modules) is registered exactly once, under its module name.
    #[test]
    fn every_experiment_module_registered_exactly_once() {
        let src = include_str!("mod.rs");
        let mut modules: Vec<&str> = src
            .lines()
            .filter_map(|l| l.trim().strip_prefix("pub mod ")?.strip_suffix(';'))
            .filter(|m| *m != "common" && *m != "registry")
            .collect();
        modules.sort_unstable();
        let mut ids: Vec<&str> = all().iter().map(|e| e.id()).collect();
        let unique: BTreeSet<&str> = ids.iter().copied().collect();
        assert_eq!(unique.len(), ids.len(), "duplicate registry ids");
        ids.sort_unstable();
        assert_eq!(
            modules, ids,
            "experiments/ modules and registry ids must match 1:1"
        );
    }

    /// The module doc table is regenerated from the registry: a newly
    /// registered experiment without its doc row fails this test.
    #[test]
    fn doc_table_matches_registry() {
        let src = include_str!("mod.rs");
        let rows: Vec<String> = src
            .lines()
            .filter(|l| l.starts_with("//! | [`"))
            .map(normalize)
            .collect();
        for exp in all() {
            let expected = normalize(&format!(
                "//! | [`{}`] | {} | {} |",
                exp.id(),
                exp.paper_item(),
                exp.what()
            ));
            assert!(
                rows.contains(&expected),
                "experiments/mod.rs doc table is missing or stale for '{}';\n\
                 expected (whitespace-normalized): {expected}",
                exp.id()
            );
        }
        assert_eq!(rows.len(), all().len(), "doc table has extra/stale rows");
    }

    /// Every registered id round-trips through the CLI resolver.
    #[test]
    fn ids_round_trip_through_resolver() {
        for exp in all() {
            let found = find(exp.id()).expect(exp.id());
            assert_eq!(found.id(), exp.id());
            assert!(!exp.paper_item().is_empty());
            assert!(!exp.what().contains('|'), "what() would break the doc table");
        }
        assert!(find("nope").is_none());
    }

    #[test]
    fn scale_resolves_once_and_stays_pinned() {
        let first = scale();
        assert_eq!(first, scale());
        // Once resolved, nothing can flip it mid-process.
        assert!(force_scale(Scale::Full).is_err() || scale() == Scale::Full);
        assert_eq!(first, scale());
    }

    #[test]
    fn run_cli_writes_consolidated_json_for_a_cheap_experiment() {
        let dir = std::env::temp_dir().join("a2cid2_registry_cli_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("BENCH_experiments.json");
        run_cli("fig6", None, Some(&path), Scale::Quick).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        assert!(text.trim_start().starts_with('['));
        assert!(text.contains("\"id\": \"fig6\""));
        assert!(text.contains("\"scale\": \"quick\""));
        assert!(text.contains("\"wall_ms\""));
        assert!(text.contains("\"rows\": ["));
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn run_cli_rejects_unknown_and_unmatched() {
        let err = run_cli("fig99", None, None, Scale::Quick).unwrap_err().to_string();
        assert!(err.contains("unknown experiment"), "{err}");
        assert!(err.contains("fig1"), "{err}");
        let err = run_cli("all", Some("zzz"), None, Scale::Quick)
            .unwrap_err()
            .to_string();
        assert!(err.contains("matches no experiment"), "{err}");
    }
}
