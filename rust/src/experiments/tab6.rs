//! Tab. 6 — run statistics with stragglers: wall time and the spread of
//! per-worker gradient counts (#∇ slowest vs fastest worker) on the
//! exponential graph.
//!
//! Paper at n = 64: AR 170 min with 14k/14k (everyone forced equal by the
//! barrier); ours 150 min with 13k/14k — async lets slow workers do less
//! instead of stalling everyone.

use crate::config::{Method, Task};
use crate::graph::Topology;
use crate::metrics::{Record, Table};

use super::common::{base_config, run_grid, GridPoint, Scale};
use super::{Report, Summary};

pub struct Tab6Row {
    pub method: &'static str,
    pub t: f64,
    pub grad_min: u64,
    pub grad_max: u64,
}

const VARIANTS: [(&str, Method, &str); 3] = [
    ("AR-SGD", Method::AllReduce, "170 min / 14k,14k"),
    ("baseline (ours)", Method::AsyncBaseline, "150 min / 13k,14k"),
    ("A2CiD2 (ours)", Method::Acid, "150 min / 13k,14k"),
];

pub fn run(scale: Scale) -> crate::Result<(Vec<Tab6Row>, Vec<Table>)> {
    let mut cfg = base_config(scale);
    cfg.topology = Topology::Exponential;
    cfg.task = Task::CifarLike;
    super::common::set_workers(&mut cfg, scale.n_max(), scale);
    cfg.compute_jitter = 0.1;

    let points: Vec<GridPoint> = VARIANTS
        .iter()
        .map(|&(_, method, _)| {
            let mut c = cfg.clone();
            c.method = method;
            GridPoint::new(c, cfg.seed)
        })
        .collect();
    let outs = run_grid(&points)?;

    let mut rows = Vec::new();
    let mut table = Table::new(
        format!(
            "Tab.6 — run statistics, exponential graph n={} (paper: async is faster; #grad spread)",
            cfg.n_workers
        ),
        &["method", "t (virtual)", "#grad slowest", "#grad fastest", "paper t / #grads"],
    );
    for ((name, _, paper), out) in VARIANTS.iter().zip(&outs) {
        let min = *out.grads_per_worker.iter().min().unwrap();
        let max = *out.grads_per_worker.iter().max().unwrap();
        table.row(&[
            (*name).into(),
            format!("{:.1}", out.t_end),
            min.to_string(),
            max.to_string(),
            (*paper).into(),
        ]);
        rows.push(Tab6Row { method: *name, t: out.t_end, grad_min: min, grad_max: max });
    }
    Ok((rows, vec![table]))
}

pub fn report(scale: Scale) -> crate::Result<Report> {
    let (rows, tables) = run(scale)?;
    let records = rows
        .iter()
        .map(|r| {
            Record::new()
                .str("method", r.method)
                .f64("t_virtual", r.t)
                .u64("grad_min", r.grad_min)
                .u64("grad_max", r.grad_max)
        })
        .collect();
    Ok(Report { tables, records, summary: Summary::default() })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn async_faster_with_grad_spread() {
        let (rows, _) = run(Scale::Quick).unwrap();
        let ar = &rows[0];
        let base = &rows[1];
        assert!(base.t < ar.t, "async {} vs AR {}", base.t, ar.t);
        // AR forces equal counts; async shows a spread under jitter.
        assert_eq!(ar.grad_min, ar.grad_max);
        assert!(base.grad_max >= base.grad_min);
    }
}
