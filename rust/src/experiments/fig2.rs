//! Fig. 2 — synchronous vs asynchronous worker schedules.
//!
//! The paper's schematic: synchronous workers idle at barriers and
//! serialize communication after computation; asynchronous workers
//! compute back-to-back and average in parallel. Regenerated here as
//! measured utilization + an ASCII timeline.

use crate::metrics::Table;
use crate::simulator::trace::{render_ascii, simulate_timeline};

use super::common::Scale;
use super::Report;

pub fn run(scale: Scale) -> crate::Result<Vec<Table>> {
    let (n, rounds) = match scale {
        Scale::Quick => (8, 12),
        Scale::Full => (16, 40),
    };
    let jitter = 0.3;
    let comm_time = 0.15;
    let sync = simulate_timeline(n, rounds, jitter, comm_time, false, 42);
    let asyn = simulate_timeline(n, rounds, jitter, comm_time, true, 42);

    println!("Fig.2 — synchronous schedule ('#' compute, '.' barrier idle, '~' blocking comm):");
    print!("{}", render_ascii(&sync, 72));
    println!("\nFig.2 — asynchronous schedule (compute back-to-back; averaging overlaps):");
    print!("{}", render_ascii(&asyn, 72));

    let mut table = Table::new(
        "Fig.2 — worker utilization (paper: async removes idle time)",
        &["schedule", "utilization", "total idle", "wall time", "#grads", "#comms"],
    );
    for (name, s) in [("synchronous (AR)", &sync), ("asynchronous (ours)", &asyn)] {
        table.row(&[
            name.into(),
            format!("{:.1}%", 100.0 * s.utilization),
            format!("{:.1}", s.total_idle),
            format!("{:.1}", s.t_end),
            format!("{}", s.n_grads),
            format!("{}", s.n_comms),
        ]);
    }
    Ok(vec![table])
}

pub fn report(scale: Scale) -> crate::Result<Report> {
    Ok(Report::from_tables(run(scale)?))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn runs_and_async_wins() {
        let tables = run(Scale::Quick).unwrap();
        assert_eq!(tables.len(), 1);
        assert_eq!(tables[0].rows.len(), 2);
    }
}
