//! Scenario sweep: where does the momentum's advantage collapse — and
//! what does per-phase adaptive (η, α̃) buy — as connectivity degrades?
//!
//! A seed-deterministic grid over **dropout fraction × switch time ×
//! adaptive-vs-frozen parameters**. Every grid point runs the same
//! ring→exponential switch scenario (the dropout window covers the
//! middle half of the run) twice with one seed: once re-deriving
//! (η, α̃) from the active phase's spectrum at each switch (`adapt=1`,
//! the default) and once holding phase-0's ring-derived values
//! (`adapt=0`). Because both arms share the seed, the Poisson event
//! sequence and mini-batch draws are identical — the comparison isolates
//! the parameter policy.
//!
//! Reported per row: final training loss, final consensus distance, and
//! the number of communication events needed to first reach the target
//! loss (a fixed fraction of the initial loss; `null` when never
//! reached). [`write_json`] emits the machine-readable
//! `BENCH_sweep.json` that CI archives next to `BENCH_perf.json`.

use std::io::Write as _;
use std::path::Path;
use std::sync::Arc;

use crate::config::{ExperimentConfig, Method, Scenario, Task};
use crate::data::{GaussianMixture, Sharding};
use crate::metrics::{Recorder, Table};
use crate::model::Logistic;
use crate::simulator::{run_simulation, SimResult};

use super::common::Scale;

/// Target loss = this fraction of the first recorded training loss.
pub const TARGET_LOSS_FRAC: f64 = 0.6;

/// One grid point × parameter policy.
pub struct SweepPoint {
    /// The full scenario string this row ran (self-describing: the
    /// frozen arm carries `;adapt=0`).
    pub scenario: String,
    pub drop_frac: f64,
    pub switch_at: f64,
    pub adaptive: bool,
    pub final_loss: f64,
    pub final_consensus: f64,
    pub n_comms: u64,
    /// Communication events spent when the training loss first dropped
    /// below the target; `None` if it never did.
    pub comms_to_target: Option<u64>,
    /// The (η, α̃) in effect at the end of the run.
    pub eta_final: f64,
    pub alpha_tilde_final: f64,
}

/// The dropout-fraction × switch-time grid for a scale.
pub fn grid(scale: Scale) -> (Vec<f64>, Vec<f64>) {
    match scale {
        Scale::Quick if cfg!(debug_assertions) => (vec![0.0, 0.3], vec![0.5]),
        Scale::Quick => (vec![0.0, 0.2, 0.4], vec![0.25, 0.5]),
        Scale::Full => (vec![0.0, 0.2, 0.4, 0.6], vec![0.25, 0.5, 0.75]),
    }
}

fn base_cfg(scale: Scale) -> ExperimentConfig {
    let (n_workers, steps) = match scale {
        Scale::Quick if cfg!(debug_assertions) => (6, 120),
        Scale::Quick => (16, 300),
        Scale::Full => (16, 800),
    };
    ExperimentConfig {
        n_workers,
        topology: crate::graph::Topology::Ring,
        method: Method::Acid,
        task: Task::CifarLike,
        comm_rate: 1.0,
        batch_size: 8,
        base_lr: 0.02,
        momentum: 0.0,
        weight_decay: 0.0,
        steps_per_worker: steps,
        sharding: Sharding::FullShuffled,
        dataset_size: 512,
        seed: 11,
        compute_jitter: 0.1,
        scenario: None,
    }
}

/// The scenario string for one grid point; `adaptive = false` appends
/// `;adapt=0` so every JSON row is reproducible from its string alone.
pub fn scenario_string(drop_frac: f64, switch_at: f64, adaptive: bool) -> String {
    let mut s = format!("ring@0,exponential@{switch_at};drop={drop_frac}:0.25:0.75:7");
    if !adaptive {
        s.push_str(";adapt=0");
    }
    s
}

/// Communication count at the first recorded sample at or after time `t`.
fn comms_at(recorder: &Recorder, t: f64) -> Option<u64> {
    recorder
        .get("comms")?
        .points
        .iter()
        .find(|(tt, _)| *tt >= t)
        .map(|(_, v)| *v as u64)
}

fn run_point(cfg: &ExperimentConfig, target_loss: f64) -> crate::Result<(SimResult, Option<u64>)> {
    let ds = Arc::new(GaussianMixture::cifar_like().sample(cfg.dataset_size, 5));
    let shards = cfg.sharding.assign(&ds, cfg.n_workers, cfg.seed);
    let model = Arc::new(Logistic::new(ds, 0.0));
    let res = run_simulation(cfg, model, &shards)?;
    let reached = res
        .recorder
        .get("train_loss")
        .and_then(|s| s.first_time_below(target_loss));
    let comms = reached.and_then(|t| comms_at(&res.recorder, t));
    Ok((res, comms))
}

pub fn run(scale: Scale) -> crate::Result<(Vec<SweepPoint>, Vec<Table>)> {
    let (drops, switches) = grid(scale);
    let base = base_cfg(scale);
    let mut points = Vec::new();
    let mut table = Table::new(
        format!(
            "Sweep — dropout × switch time × adaptive-vs-frozen (η, α̃), \
             n={}, ring→exponential, seed {}",
            base.n_workers, base.seed
        ),
        &[
            "drop",
            "switch@",
            "cons (frozen)",
            "cons (adaptive)",
            "#comm→target (frozen)",
            "#comm→target (adaptive)",
            "adaptive no worse",
        ],
    );
    for &drop_frac in &drops {
        for &switch_at in &switches {
            // Run the frozen arm first to fix the target loss; both arms
            // share the seed, so their pre-switch trajectories (and the
            // initial loss) are identical.
            let mut per_arm: Vec<(bool, SimResult, Option<u64>, String)> = Vec::new();
            let mut target = f64::NAN;
            for adaptive in [false, true] {
                let s = scenario_string(drop_frac, switch_at, adaptive);
                let mut cfg = base.clone();
                cfg.scenario = Some(Scenario::parse(&s)?);
                if target.is_nan() {
                    // Probe the initial loss from the first recorded
                    // point of this arm's own run (recorded before any
                    // parameter divergence can matter).
                    let (res, _) = run_point(&cfg, f64::NEG_INFINITY)?;
                    let first = res
                        .recorder
                        .get("train_loss")
                        .and_then(|ser| ser.points.first().copied())
                        .map(|(_, v)| v)
                        .unwrap_or(f64::NAN);
                    target = TARGET_LOSS_FRAC * first;
                    let comms = res
                        .recorder
                        .get("train_loss")
                        .and_then(|ser| ser.first_time_below(target))
                        .and_then(|t| comms_at(&res.recorder, t));
                    per_arm.push((adaptive, res, comms, s));
                    continue;
                }
                let (res, comms) = run_point(&cfg, target)?;
                per_arm.push((adaptive, res, comms, s));
            }
            for (adaptive, res, comms, s) in &per_arm {
                points.push(SweepPoint {
                    scenario: s.clone(),
                    drop_frac,
                    switch_at,
                    adaptive: *adaptive,
                    final_loss: res.final_loss(),
                    final_consensus: res.final_consensus(),
                    n_comms: res.n_comms,
                    comms_to_target: *comms,
                    eta_final: res.acid.eta,
                    alpha_tilde_final: res.acid.alpha_tilde,
                });
            }
            let frozen = &per_arm[0];
            let adaptive = &per_arm[1];
            let fmt_comms =
                |c: &Option<u64>| c.map_or("never".to_string(), |v| v.to_string());
            let no_worse = adaptive.1.final_consensus
                <= frozen.1.final_consensus * 1.05 + 1e-3;
            table.row(&[
                format!("{drop_frac}"),
                format!("{switch_at}"),
                format!("{:.4}", frozen.1.final_consensus),
                format!("{:.4}", adaptive.1.final_consensus),
                fmt_comms(&frozen.2),
                fmt_comms(&adaptive.2),
                if no_worse { "yes".into() } else { "NO".into() },
            ]);
        }
    }
    Ok((points, vec![table]))
}

/// Write the machine-readable sweep rows (the `BENCH_sweep.json`
/// artifact CI archives).
pub fn write_json(points: &[SweepPoint], path: &Path) -> std::io::Result<()> {
    let mut f = std::io::BufWriter::new(std::fs::File::create(path)?);
    writeln!(f, "[")?;
    for (i, p) in points.iter().enumerate() {
        let comma = if i + 1 == points.len() { "" } else { "," };
        let comms = p
            .comms_to_target
            .map_or("null".to_string(), |v| v.to_string());
        writeln!(
            f,
            "  {{\"scenario\": \"{}\", \"drop\": {}, \"switch_at\": {}, \
             \"adaptive\": {}, \"final_loss\": {:.6}, \"final_consensus\": {:.6}, \
             \"n_comms\": {}, \"comms_to_target\": {}, \"eta_final\": {:.6}, \
             \"alpha_tilde_final\": {:.6}}}{comma}",
            p.scenario,
            p.drop_frac,
            p.switch_at,
            p.adaptive,
            p.final_loss,
            p.final_consensus,
            p.n_comms,
            comms,
            p.eta_final,
            p.alpha_tilde_final,
        )?;
    }
    writeln!(f, "]")?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn smoke_grid_adaptive_no_worse_on_every_point() {
        let (points, tables) = run(Scale::Quick).unwrap();
        let (drops, switches) = grid(Scale::Quick);
        assert_eq!(points.len(), 2 * drops.len() * switches.len());
        assert_eq!(tables.len(), 1);
        for pair in points.chunks(2) {
            let (frozen, adaptive) = (&pair[0], &pair[1]);
            assert!(!frozen.adaptive && adaptive.adaptive);
            assert_eq!(frozen.drop_frac, adaptive.drop_frac);
            assert!(frozen.final_loss.is_finite() && adaptive.final_loss.is_finite());
            assert!(
                frozen.final_consensus.is_finite() && adaptive.final_consensus.is_finite()
            );
            // The acceptance bar: per-phase adaptive (η, α̃) is no worse
            // than frozen phase-0 parameters on every smoke grid point.
            // Both arms replay the identical event sequence (shared
            // seed), so the slack only absorbs f32 accumulation noise.
            assert!(
                adaptive.final_consensus <= frozen.final_consensus * 1.25 + 0.05,
                "adaptive must not lose at drop={} switch={}: {} vs {}",
                adaptive.drop_frac,
                adaptive.switch_at,
                adaptive.final_consensus,
                frozen.final_consensus
            );
            assert!(
                adaptive.final_loss <= frozen.final_loss * 1.25 + 0.05,
                "adaptive loss regressed at drop={} switch={}",
                adaptive.drop_frac,
                adaptive.switch_at
            );
            // The frozen arm really is frozen: its final α̃ is phase-0's
            // ring-derived value (> ½); the adaptive arm ends on the
            // exponential graph's flatter spectrum.
            assert!(frozen.alpha_tilde_final > 0.5);
            assert!(adaptive.alpha_tilde_final < frozen.alpha_tilde_final);
        }
    }

    #[test]
    fn json_rows_render() {
        let p = SweepPoint {
            scenario: scenario_string(0.2, 0.5, false),
            drop_frac: 0.2,
            switch_at: 0.5,
            adaptive: false,
            final_loss: 1.25,
            final_consensus: 0.5,
            n_comms: 100,
            comms_to_target: None,
            eta_final: 0.3,
            alpha_tilde_final: 0.9,
        };
        let dir = std::env::temp_dir().join("a2cid2_sweep_test.json");
        write_json(&[p], &dir).unwrap();
        let text = std::fs::read_to_string(&dir).unwrap();
        assert!(text.contains("\"comms_to_target\": null"));
        assert!(text.contains("adapt=0"));
        assert!(text.trim_start().starts_with('['));
        let _ = std::fs::remove_file(&dir);
    }
}
