//! Scenario sweep: where does the momentum's advantage collapse — and
//! what does per-phase adaptive (η, α̃) buy — as connectivity degrades?
//!
//! A seed-deterministic grid over **dropout fraction × switch time ×
//! worker churn × adaptive-vs-frozen parameters**. Every grid point runs
//! the same ring→exponential switch scenario (the dropout window covers
//! the middle half of the run; the churn arm additionally sends a
//! quarter of the fleet away mid-run and re-joins it near the end) twice
//! with one seed: once re-deriving (η, α̃) from the active phase's
//! spectrum at each switch (`adapt=1`, the default) and once holding
//! phase-0's ring-derived values (`adapt=0`). Because both arms share
//! the seed, the Poisson event sequence and mini-batch draws are
//! identical — the comparison isolates the parameter policy. Grid units
//! fan out across the deterministic [`super::common::GridRunner`] pool.
//!
//! Reported per row: final training loss, final consensus distance, and
//! the number of communication events needed to first reach the target
//! loss (a fixed fraction of the initial loss; `null` when never
//! reached). The registry entry declares `BENCH_sweep.json` as this
//! experiment's artifact, so every CLI/bench run emits the
//! machine-readable rows ([`SweepPoint::record`]) that CI archives next
//! to `BENCH_perf.json`.

use std::sync::Arc;

use crate::config::{ExperimentConfig, Method, Scenario, Task};
use crate::data::{GaussianMixture, Sharding};
use crate::metrics::{Record, Table};
use crate::model::Logistic;
use crate::simulator::{run_simulation, SimResult};

use super::common::{comms_at, GridRunner, Scale};
use super::{Report, Summary};

/// Target loss = this fraction of the first recorded training loss.
pub const TARGET_LOSS_FRAC: f64 = 0.6;

/// One grid point × parameter policy.
pub struct SweepPoint {
    /// The full scenario string this row ran (self-describing: the
    /// frozen arm carries `;adapt=0`, the churn arm `leave=`/`join=`).
    pub scenario: String,
    pub drop_frac: f64,
    pub switch_at: f64,
    /// Whether this row ran the worker-churn arm (25% leave at t=0.3,
    /// re-join at t=0.8).
    pub churn: bool,
    pub adaptive: bool,
    pub final_loss: f64,
    pub final_consensus: f64,
    pub n_comms: u64,
    /// Communication events spent when the training loss first dropped
    /// below the target; `None` if it never did.
    pub comms_to_target: Option<u64>,
    /// The (η, α̃) in effect at the end of the run.
    pub eta_final: f64,
    pub alpha_tilde_final: f64,
}

impl SweepPoint {
    /// The `BENCH_sweep.json` row.
    pub fn record(&self) -> Record {
        Record::new()
            .str("scenario", self.scenario.clone())
            .f64("drop", self.drop_frac)
            .f64("switch_at", self.switch_at)
            .bool("churn", self.churn)
            .bool("adaptive", self.adaptive)
            .f64("final_loss", self.final_loss)
            .f64("final_consensus", self.final_consensus)
            .u64("n_comms", self.n_comms)
            .opt_u64("comms_to_target", self.comms_to_target)
            .f64("eta_final", self.eta_final)
            .f64("alpha_tilde_final", self.alpha_tilde_final)
    }
}

/// The dropout-fraction × switch-time × churn grid for a scale.
pub fn grid(scale: Scale) -> (Vec<f64>, Vec<f64>, Vec<bool>) {
    match scale {
        Scale::Quick if cfg!(debug_assertions) => (vec![0.0, 0.3], vec![0.5], vec![false, true]),
        Scale::Quick => (vec![0.0, 0.2, 0.4], vec![0.25, 0.5], vec![false, true]),
        Scale::Full => (vec![0.0, 0.2, 0.4, 0.6], vec![0.25, 0.5, 0.75], vec![false, true]),
    }
}

fn base_cfg(scale: Scale) -> ExperimentConfig {
    let (n_workers, steps) = match scale {
        Scale::Quick if cfg!(debug_assertions) => (6, 120),
        Scale::Quick => (16, 300),
        Scale::Full => (16, 800),
    };
    ExperimentConfig {
        n_workers,
        topology: crate::graph::Topology::Ring,
        method: Method::Acid,
        task: Task::CifarLike,
        comm_rate: 1.0,
        batch_size: 8,
        base_lr: 0.02,
        momentum: 0.0,
        weight_decay: 0.0,
        steps_per_worker: steps,
        sharding: Sharding::FullShuffled,
        dataset_size: 512,
        seed: 11,
        compute_jitter: 0.1,
        scenario: None,
        algorithm: None,
    }
}

/// The scenario string for one grid point; `churn` adds the ROADMAP's
/// leave/join arm and `adaptive = false` appends `;adapt=0`, so every
/// JSON row is reproducible from its string alone.
pub fn scenario_string(drop_frac: f64, switch_at: f64, churn: bool, adaptive: bool) -> String {
    let mut s = format!("ring@0,exponential@{switch_at};drop={drop_frac}:0.25:0.75:7");
    if churn {
        s.push_str(";leave=0.25:0.3:1;join=0.25:0.8");
    }
    if !adaptive {
        s.push_str(";adapt=0");
    }
    s
}

fn run_point(cfg: &ExperimentConfig, target_loss: f64) -> crate::Result<(SimResult, Option<u64>)> {
    let ds = Arc::new(GaussianMixture::cifar_like().sample(cfg.dataset_size, 5));
    let shards = cfg.sharding.assign(&ds, cfg.n_workers, cfg.seed);
    let model = Arc::new(Logistic::new(ds, 0.0));
    let res = run_simulation(cfg, model, &shards)?;
    let reached = res
        .recorder
        .get("train_loss")
        .and_then(|s| s.first_time_below(target_loss));
    let comms = reached.and_then(|t| comms_at(&res.recorder, t));
    Ok((res, comms))
}

/// One grid unit: both parameter-policy arms at a fixed
/// (drop, switch, churn), frozen first (it pins the target loss), on the
/// shared seed. Returns the two [`SweepPoint`]s in `[frozen, adaptive]`
/// order.
fn run_unit(
    base: &ExperimentConfig,
    drop_frac: f64,
    switch_at: f64,
    churn: bool,
) -> crate::Result<Vec<SweepPoint>> {
    let mut points = Vec::with_capacity(2);
    let mut target = f64::NAN;
    for adaptive in [false, true] {
        let s = scenario_string(drop_frac, switch_at, churn, adaptive);
        let mut cfg = base.clone();
        cfg.scenario = Some(Scenario::parse(&s)?);
        let (res, comms) = if target.is_nan() {
            // Probe the initial loss from the first recorded point of
            // this arm's own run (recorded before any parameter
            // divergence can matter) to fix the shared target.
            let (res, _) = run_point(&cfg, f64::NEG_INFINITY)?;
            let first = res
                .recorder
                .get("train_loss")
                .and_then(|ser| ser.points.first().copied())
                .map(|(_, v)| v)
                .unwrap_or(f64::NAN);
            target = TARGET_LOSS_FRAC * first;
            let comms = res
                .recorder
                .get("train_loss")
                .and_then(|ser| ser.first_time_below(target))
                .and_then(|t| comms_at(&res.recorder, t));
            (res, comms)
        } else {
            run_point(&cfg, target)?
        };
        points.push(SweepPoint {
            scenario: s,
            drop_frac,
            switch_at,
            churn,
            adaptive,
            final_loss: res.final_loss(),
            final_consensus: res.final_consensus(),
            n_comms: res.n_comms,
            comms_to_target: comms,
            eta_final: res.acid.eta,
            alpha_tilde_final: res.acid.alpha_tilde,
        });
    }
    Ok(points)
}

pub fn run(scale: Scale) -> crate::Result<(Vec<SweepPoint>, Vec<Table>)> {
    let (drops, switches, churns) = grid(scale);
    let base = base_cfg(scale);
    let mut units = Vec::new();
    for &drop_frac in &drops {
        for &switch_at in &switches {
            for &churn in &churns {
                units.push((drop_frac, switch_at, churn));
            }
        }
    }
    let unit_points = GridRunner::from_env().run(&units, |&(drop_frac, switch_at, churn)| {
        run_unit(&base, drop_frac, switch_at, churn)
    })?;

    let mut table = Table::new(
        format!(
            "Sweep — dropout × switch time × churn × adaptive-vs-frozen (η, α̃), \
             n={}, ring→exponential, seed {}",
            base.n_workers, base.seed
        ),
        &[
            "drop",
            "switch@",
            "churn",
            "cons (frozen)",
            "cons (adaptive)",
            "#comm→target (frozen)",
            "#comm→target (adaptive)",
            "adaptive no worse",
        ],
    );
    let mut points = Vec::with_capacity(units.len() * 2);
    for pair in unit_points {
        let (frozen, adaptive) = (&pair[0], &pair[1]);
        let fmt_comms =
            |c: &Option<u64>| c.map_or("never".to_string(), |v| v.to_string());
        let no_worse =
            adaptive.final_consensus <= frozen.final_consensus * 1.05 + 1e-3;
        table.row(&[
            frozen.drop_frac.to_string(),
            frozen.switch_at.to_string(),
            if frozen.churn { "yes".into() } else { "no".into() },
            format!("{:.4}", frozen.final_consensus),
            format!("{:.4}", adaptive.final_consensus),
            fmt_comms(&frozen.comms_to_target),
            fmt_comms(&adaptive.comms_to_target),
            if no_worse { "yes".into() } else { "NO".into() },
        ]);
        points.extend(pair);
    }
    Ok((points, vec![table]))
}

pub fn report(scale: Scale) -> crate::Result<Report> {
    let (points, tables) = run(scale)?;
    let records = points.iter().map(SweepPoint::record).collect();
    let summary = Summary {
        final_loss: points.last().map(|p| p.final_loss),
        final_consensus: points.last().map(|p| p.final_consensus),
        ..Summary::default()
    };
    Ok(Report { tables, records, summary })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn smoke_grid_adaptive_no_worse_on_every_point() {
        let (points, tables) = run(Scale::Quick).unwrap();
        let (drops, switches, churns) = grid(Scale::Quick);
        assert_eq!(points.len(), 2 * drops.len() * switches.len() * churns.len());
        assert_eq!(tables.len(), 1);
        for pair in points.chunks(2) {
            let (frozen, adaptive) = (&pair[0], &pair[1]);
            assert!(!frozen.adaptive && adaptive.adaptive);
            assert_eq!(frozen.drop_frac, adaptive.drop_frac);
            assert_eq!(frozen.churn, adaptive.churn);
            assert!(frozen.final_loss.is_finite() && adaptive.final_loss.is_finite());
            assert!(
                frozen.final_consensus.is_finite() && adaptive.final_consensus.is_finite()
            );
            // The acceptance bar: per-phase adaptive (η, α̃) is no worse
            // than frozen phase-0 parameters on every smoke grid point.
            // Both arms replay the identical event sequence (shared
            // seed), so the slack only absorbs f32 accumulation noise.
            assert!(
                adaptive.final_consensus <= frozen.final_consensus * 1.25 + 0.05,
                "adaptive must not lose at drop={} switch={} churn={}: {} vs {}",
                adaptive.drop_frac,
                adaptive.switch_at,
                adaptive.churn,
                adaptive.final_consensus,
                frozen.final_consensus
            );
            assert!(
                adaptive.final_loss <= frozen.final_loss * 1.25 + 0.05,
                "adaptive loss regressed at drop={} switch={} churn={}",
                adaptive.drop_frac,
                adaptive.switch_at,
                adaptive.churn
            );
            // The frozen arm really is frozen: its final α̃ is phase-0's
            // ring-derived value (> ½); the adaptive arm ends on the
            // exponential graph's flatter spectrum.
            assert!(frozen.alpha_tilde_final > 0.5);
            assert!(adaptive.alpha_tilde_final < frozen.alpha_tilde_final);
        }
    }

    #[test]
    fn churn_scenarios_round_trip_the_parser() {
        for adaptive in [false, true] {
            let s = scenario_string(0.2, 0.5, true, adaptive);
            assert!(s.contains("leave=0.25:0.3:1"), "{s}");
            assert!(s.contains("join=0.25:0.8"), "{s}");
            let parsed = crate::config::Scenario::parse(&s).unwrap();
            assert_eq!(parsed.churn.len(), 2);
            assert_eq!(parsed.adaptive, adaptive);
        }
    }

    #[test]
    fn json_rows_render() {
        // The artifact path: SweepPoint::record rows rendered by the
        // registry through metrics::render_records.
        let p = SweepPoint {
            scenario: scenario_string(0.2, 0.5, true, false),
            drop_frac: 0.2,
            switch_at: 0.5,
            churn: true,
            adaptive: false,
            final_loss: 1.25,
            final_consensus: 0.5,
            n_comms: 100,
            comms_to_target: None,
            eta_final: 0.3,
            alpha_tilde_final: 0.9,
        };
        let text = crate::metrics::render_records(&[p.record()]);
        assert!(text.contains("\"comms_to_target\": null"));
        assert!(text.contains("\"churn\": true"));
        assert!(text.contains("adapt=0"));
        assert!(text.contains("leave=0.25"));
        assert!(text.trim_start().starts_with('['));
    }
}
