//! Tab. 5 — ImageNet-like held-out accuracy: the challenging ring at
//! rates 1 and 2 com/∇, with and without A²CiD², plus the AR-SGD and
//! complete-graph references.
//!
//! Paper shape at n = 64: AR 74.5; complete baseline 71.3; ring baseline
//! 64.1 → A²CiD² 68.0 (rate 1); ring baseline 68.2 → A²CiD² 71.4
//! (rate 2) — the momentum recovers ~4 points and stacks with rate.

use crate::config::{Method, Task};
use crate::graph::Topology;
use crate::metrics::Table;

use super::common::{base_config, over_seeds, Scale};

pub fn run(scale: Scale) -> crate::Result<Vec<Table>> {
    let mut cfg = base_config(scale);
    cfg.task = Task::ImagenetLike;
    cfg.dataset_size = 8192;

    let grid: Vec<usize> = match scale {
        Scale::Quick => vec![8, 16],
        Scale::Full => vec![16, 32, 64],
    };
    let mut header: Vec<String> = vec!["variant".into(), "com/grad".into()];
    header.extend(grid.iter().map(|n| format!("n={n}")));
    let header_refs: Vec<&str> = header.iter().map(|s| s.as_str()).collect();
    let mut table = Table::new(
        "Tab.5 — ImageNet-like held-out accuracy (paper: ring degrades; A2CiD2 + rate recover)",
        &header_refs,
    );

    let variants: Vec<(String, Topology, Method, f64)> = vec![
        ("AR-SGD".into(), Topology::Complete, Method::AllReduce, 0.0),
        ("complete / baseline".into(), Topology::Complete, Method::AsyncBaseline, 1.0),
        ("ring / baseline".into(), Topology::Ring, Method::AsyncBaseline, 1.0),
        ("ring / A2CiD2".into(), Topology::Ring, Method::Acid, 1.0),
        ("ring / baseline".into(), Topology::Ring, Method::AsyncBaseline, 2.0),
        ("ring / A2CiD2".into(), Topology::Ring, Method::Acid, 2.0),
    ];
    for (name, topo, method, rate) in variants {
        let mut cells = vec![
            name,
            if method == Method::AllReduce { "-".into() } else { format!("{rate}") },
        ];
        for &n in &grid {
            super::common::set_workers(&mut cfg, n, scale);
            cfg.topology = topo.clone();
            cfg.method = method;
            cfg.comm_rate = if rate == 0.0 { 1.0 } else { rate };
            let stats = over_seeds(scale, &cfg, |o| 100.0 * o.accuracy.unwrap_or(f64::NAN))?;
            cells.push(stats.pm(1));
        }
        table.row(&cells);
    }
    Ok(vec![table])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table_shape() {
        let tables = run(Scale::Quick).unwrap();
        assert_eq!(tables[0].rows.len(), 6);
        for row in &tables[0].rows {
            for cell in &row[2..] {
                let acc: f64 = cell.split('±').next().unwrap().parse().unwrap();
                assert!(acc > 3.0, "{}: {cell} (chance = 1%)", row[0]);
            }
        }
    }
}
