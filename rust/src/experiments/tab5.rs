//! Tab. 5 — ImageNet-like held-out accuracy: the challenging ring at
//! rates 1 and 2 com/∇, with and without A²CiD², plus the AR-SGD and
//! complete-graph references.
//!
//! Paper shape at n = 64: AR 74.5; complete baseline 71.3; ring baseline
//! 64.1 → A²CiD² 68.0 (rate 1); ring baseline 68.2 → A²CiD² 71.4
//! (rate 2) — the momentum recovers ~4 points and stacks with rate.

use crate::config::{Method, Task};
use crate::graph::Topology;
use crate::metrics::{Record, Stats, Table};

use super::common::{base_config, set_workers, variant_grid_cells, Scale};
use super::{Report, Summary};

fn variants() -> Vec<(String, Topology, Method, f64)> {
    vec![
        ("AR-SGD".into(), Topology::Complete, Method::AllReduce, 0.0),
        ("complete / baseline".into(), Topology::Complete, Method::AsyncBaseline, 1.0),
        ("ring / baseline".into(), Topology::Ring, Method::AsyncBaseline, 1.0),
        ("ring / A2CiD2".into(), Topology::Ring, Method::Acid, 1.0),
        ("ring / baseline".into(), Topology::Ring, Method::AsyncBaseline, 2.0),
        ("ring / A2CiD2".into(), Topology::Ring, Method::Acid, 2.0),
    ]
}

fn n_grid(scale: Scale) -> Vec<usize> {
    match scale {
        Scale::Quick => vec![8, 16],
        Scale::Full => vec![16, 32, 64],
    }
}

/// Variant label + rate → one accuracy cell per grid n.
type AccuracyRows = Vec<(String, f64, Vec<Stats>)>;

/// (variant × n) accuracy cells, aggregated over the scale's seeds, in
/// declaration order (variant-major).
fn accuracy_grid(scale: Scale) -> crate::Result<(Vec<usize>, AccuracyRows)> {
    let cfg = {
        let mut c = base_config(scale);
        c.task = Task::ImagenetLike;
        c.dataset_size = 8192;
        c
    };
    let grid = n_grid(scale);
    let variants = variants();
    let cells = variant_grid_cells(
        &variants,
        &grid,
        &scale.seeds(),
        |(_, topo, method, rate), n| {
            let mut c = cfg.clone();
            set_workers(&mut c, n, scale);
            c.topology = topo.clone();
            c.method = *method;
            c.comm_rate = if *rate == 0.0 { 1.0 } else { *rate };
            c
        },
        |o| 100.0 * o.accuracy.unwrap_or(f64::NAN),
    )?;
    let rows = variants
        .into_iter()
        .zip(cells.chunks(grid.len()))
        .map(|((name, _, _, rate), row)| (name, rate, row.to_vec()))
        .collect();
    Ok((grid, rows))
}

fn tables_from(grid: &[usize], rows: &[(String, f64, Vec<Stats>)]) -> Vec<Table> {
    let mut header: Vec<String> = vec!["variant".into(), "com/grad".into()];
    header.extend(grid.iter().map(|n| format!("n={n}")));
    let header_refs: Vec<&str> = header.iter().map(|s| s.as_str()).collect();
    let mut table = Table::new(
        "Tab.5 — ImageNet-like held-out accuracy (paper: ring degrades; A2CiD2 + rate recover)",
        &header_refs,
    );
    for (name, rate, cells) in rows {
        let mut row = vec![
            name.clone(),
            if *rate == 0.0 { "-".into() } else { format!("{rate}") },
        ];
        row.extend(cells.iter().map(|s| s.pm(1)));
        table.row(&row);
    }
    vec![table]
}

pub fn run(scale: Scale) -> crate::Result<Vec<Table>> {
    let (grid, rows) = accuracy_grid(scale)?;
    Ok(tables_from(&grid, &rows))
}

pub fn report(scale: Scale) -> crate::Result<Report> {
    let (grid, rows) = accuracy_grid(scale)?;
    let mut records = Vec::new();
    for (name, rate, cells) in &rows {
        for (&n, stats) in grid.iter().zip(cells) {
            records.push(
                Record::new()
                    .str("variant", name.clone())
                    .f64("comm_rate", if *rate == 0.0 { 1.0 } else { *rate })
                    .u64("n", n as u64)
                    .f64("accuracy", stats.mean)
                    .f64("accuracy_std", stats.std),
            );
        }
    }
    let summary = Summary {
        // Headline: ring / A2CiD2 @ rate 2 at the largest n.
        accuracy: rows.last().and_then(|(_, _, cells)| cells.last()).map(|s| s.mean),
        ..Summary::default()
    };
    Ok(Report { tables: tables_from(&grid, &rows), records, summary })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table_shape() {
        let tables = run(Scale::Quick).unwrap();
        assert_eq!(tables[0].rows.len(), 6);
        for row in &tables[0].rows {
            for cell in &row[2..] {
                let acc: f64 = cell.split('±').next().unwrap().parse().unwrap();
                assert!(acc > 3.0, "{}: {cell} (chance = 1%)", row[0]);
            }
        }
    }
}
