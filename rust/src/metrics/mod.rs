//! Metrics recording: time-series, summary statistics, CSV output, and
//! the typed JSON [`Record`]s the experiment registry emits.
//!
//! No serde offline, so serialization is hand-rolled: long-format CSV for
//! curves (what the paper-figure regeneration scripts consume) and a
//! minimal JSON writer for machine-readable experiment artifacts
//! (`BENCH_experiments.json`, `BENCH_sweep.json`).

use std::io::Write;
use std::path::Path;

/// A named time-series of `(t, value)` points.
#[derive(Clone, Debug, Default)]
pub struct Series {
    pub name: String,
    pub points: Vec<(f64, f64)>,
}

impl Series {
    pub fn new(name: impl Into<String>) -> Self {
        Self { name: name.into(), points: Vec::new() }
    }

    pub fn push(&mut self, t: f64, v: f64) {
        self.points.push((t, v));
    }

    pub fn last(&self) -> Option<(f64, f64)> {
        self.points.last().copied()
    }

    /// Mean of the values in the final `frac` fraction of the time axis
    /// (used to report "final loss" robustly against event noise).
    pub fn tail_mean(&self, frac: f64) -> f64 {
        if self.points.is_empty() {
            return f64::NAN;
        }
        let t_end = self.points.last().unwrap().0;
        let t_cut = t_end * (1.0 - frac);
        let tail: Vec<f64> = self
            .points
            .iter()
            .filter(|(t, _)| *t >= t_cut)
            .map(|(_, v)| *v)
            .collect();
        if tail.is_empty() {
            return self.points.last().unwrap().1;
        }
        tail.iter().sum::<f64>() / tail.len() as f64
    }

    /// First time the series drops (and stays, for one sample) below `thr`;
    /// None if it never does. Used for time-to-ε measurements (Tab. 1).
    pub fn first_time_below(&self, thr: f64) -> Option<f64> {
        self.points.iter().find(|(_, v)| *v < thr).map(|(t, _)| *t)
    }

    /// Down-sample to at most `max_points` by uniform stride (for CSV dumps).
    pub fn downsample(&self, max_points: usize) -> Series {
        if self.points.len() <= max_points || max_points == 0 {
            return self.clone();
        }
        let stride = self.points.len().div_ceil(max_points);
        Series {
            name: self.name.clone(),
            points: self.points.iter().step_by(stride).copied().collect(),
        }
    }
}

/// A recorder holding many series keyed by name.
#[derive(Clone, Debug, Default)]
pub struct Recorder {
    pub series: Vec<Series>,
}

impl Recorder {
    pub fn new() -> Self {
        Self::default()
    }

    /// Get-or-create a series by name.
    pub fn series_mut(&mut self, name: &str) -> &mut Series {
        if let Some(pos) = self.series.iter().position(|s| s.name == name) {
            return &mut self.series[pos];
        }
        self.series.push(Series::new(name));
        self.series.last_mut().unwrap()
    }

    pub fn get(&self, name: &str) -> Option<&Series> {
        self.series.iter().find(|s| s.name == name)
    }

    pub fn record(&mut self, name: &str, t: f64, v: f64) {
        self.series_mut(name).push(t, v);
    }

    /// Write all series as long-format CSV: `series,t,value`.
    pub fn write_csv(&self, path: &Path, max_points_per_series: usize) -> crate::Result<()> {
        if let Some(parent) = path.parent() {
            std::fs::create_dir_all(parent)?;
        }
        let mut f = std::io::BufWriter::new(std::fs::File::create(path)?);
        writeln!(f, "series,t,value")?;
        for s in &self.series {
            for (t, v) in s.downsample(max_points_per_series).points {
                writeln!(f, "{},{t},{v}", s.name)?;
            }
        }
        Ok(())
    }
}

/// Summary statistics over a sample.
#[derive(Clone, Copy, Debug, Default)]
pub struct Stats {
    pub n: usize,
    pub mean: f64,
    pub std: f64,
    pub min: f64,
    pub max: f64,
}

impl Stats {
    pub fn of(values: &[f64]) -> Stats {
        if values.is_empty() {
            return Stats::default();
        }
        let n = values.len();
        let mean = values.iter().sum::<f64>() / n as f64;
        let var = values.iter().map(|v| (v - mean).powi(2)).sum::<f64>()
            / (n.max(2) - 1) as f64;
        Stats {
            n,
            mean,
            std: var.sqrt(),
            min: values.iter().cloned().fold(f64::INFINITY, f64::min),
            max: values.iter().cloned().fold(f64::NEG_INFINITY, f64::max),
        }
    }

    /// Format as `mean ± std` with the given precision, like the paper's
    /// tables.
    pub fn pm(&self, digits: usize) -> String {
        format!("{:.d$}±{:.d$}", self.mean, self.std, d = digits)
    }
}

/// Quantile of a sample (linear interpolation, `q` in [0,1]).
pub fn quantile(values: &[f64], q: f64) -> f64 {
    assert!(!values.is_empty());
    let mut v: Vec<f64> = values.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let pos = q.clamp(0.0, 1.0) * (v.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    if lo == hi {
        v[lo]
    } else {
        v[lo] + (v[hi] - v[lo]) * (pos - lo as f64)
    }
}

/// Simple fixed-width table printer used by every bench to mirror the
/// paper's tables.
pub struct Table {
    pub title: String,
    pub header: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: impl Into<String>, header: &[&str]) -> Self {
        Self {
            title: title.into(),
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.header.len(), "row width mismatch");
        self.rows.push(cells.to_vec());
    }

    pub fn render(&self) -> String {
        let ncol = self.header.len();
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (c, cell) in row.iter().enumerate() {
                widths[c] = widths[c].max(cell.len());
            }
        }
        let mut out = String::new();
        out.push_str(&format!("## {}\n", self.title));
        let fmt_row = |cells: &[String]| -> String {
            let mut line = String::from("|");
            for c in 0..ncol {
                line.push_str(&format!(" {:<w$} |", cells[c], w = widths[c]));
            }
            line.push('\n');
            line
        };
        out.push_str(&fmt_row(&self.header));
        let mut sep = String::from("|");
        for w in &widths {
            sep.push_str(&format!("{}|", "-".repeat(w + 2)));
        }
        sep.push('\n');
        out.push_str(&sep);
        for row in &self.rows {
            out.push_str(&fmt_row(row));
        }
        out
    }

    pub fn print(&self) {
        println!("{}", self.render());
    }

    /// Bridge the human table into typed [`Record`]s: one record per row,
    /// keyed by the column headers (plus a `table` field carrying the
    /// title), with cells that parse as numbers emitted as numbers. This
    /// is how experiments without a hand-written record set still produce
    /// machine-readable rows for `BENCH_experiments.json`.
    pub fn to_records(&self) -> Vec<Record> {
        self.rows
            .iter()
            .map(|row| {
                let mut rec = Record::new().str("table", self.title.clone());
                for (header, cell) in self.header.iter().zip(row) {
                    let value = match cell.parse::<f64>() {
                        Ok(v) => Value::F64(v),
                        Err(_) => Value::Str(cell.clone()),
                    };
                    rec = rec.field(header.clone(), value);
                }
                rec
            })
            .collect()
    }
}

/// A typed, serde-free JSON value. Only what the experiment artifacts
/// need: scalars, strings, null, and nested record arrays.
#[derive(Clone, Debug, PartialEq)]
pub enum Value {
    Null,
    Bool(bool),
    U64(u64),
    F64(f64),
    Str(String),
    /// A nested array of records (e.g. the consolidated artifact's
    /// per-experiment row sets).
    Records(Vec<Record>),
}

impl Value {
    /// Numeric view: floats as-is, integers widened; everything else
    /// (strings, bools, null, nested rows) is `None`. The conformance
    /// oracle reads observed metrics through this.
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::F64(v) => Some(*v),
            Value::U64(v) => Some(*v as f64),
            _ => None,
        }
    }

    fn render(&self, out: &mut String) {
        match self {
            Value::Null => out.push_str("null"),
            Value::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Value::U64(v) => out.push_str(&v.to_string()),
            // NaN/inf are not JSON; a non-finite measurement renders null.
            Value::F64(v) if !v.is_finite() => out.push_str("null"),
            Value::F64(v) => out.push_str(&v.to_string()),
            Value::Str(s) => render_json_str(s, out),
            Value::Records(rows) => {
                out.push('[');
                for (i, r) in rows.iter().enumerate() {
                    if i > 0 {
                        out.push_str(", ");
                    }
                    r.render(out);
                }
                out.push(']');
            }
        }
    }
}

fn render_json_str(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            '\r' => out.push_str("\\r"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

/// One JSON object with ordered fields — the unit of every
/// machine-readable experiment artifact. Built with the chaining setters
/// (`.str(..)`, `.f64(..)`, …); field order is emission order.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct Record {
    pub fields: Vec<(String, Value)>,
}

impl Record {
    pub fn new() -> Record {
        Record::default()
    }

    pub fn field(mut self, key: impl Into<String>, value: Value) -> Record {
        self.fields.push((key.into(), value));
        self
    }

    pub fn str(self, key: impl Into<String>, v: impl Into<String>) -> Record {
        self.field(key, Value::Str(v.into()))
    }

    pub fn f64(self, key: impl Into<String>, v: f64) -> Record {
        self.field(key, Value::F64(v))
    }

    pub fn u64(self, key: impl Into<String>, v: u64) -> Record {
        self.field(key, Value::U64(v))
    }

    pub fn bool(self, key: impl Into<String>, v: bool) -> Record {
        self.field(key, Value::Bool(v))
    }

    /// `None` renders as JSON `null`.
    pub fn opt_f64(self, key: impl Into<String>, v: Option<f64>) -> Record {
        self.field(key, v.map_or(Value::Null, Value::F64))
    }

    /// `None` renders as JSON `null`.
    pub fn opt_u64(self, key: impl Into<String>, v: Option<u64>) -> Record {
        self.field(key, v.map_or(Value::Null, Value::U64))
    }

    pub fn records(self, key: impl Into<String>, rows: Vec<Record>) -> Record {
        self.field(key, Value::Records(rows))
    }

    pub fn get(&self, key: &str) -> Option<&Value> {
        self.fields.iter().find(|(k, _)| k == key).map(|(_, v)| v)
    }

    fn render(&self, out: &mut String) {
        out.push('{');
        for (i, (k, v)) in self.fields.iter().enumerate() {
            if i > 0 {
                out.push_str(", ");
            }
            render_json_str(k, out);
            out.push_str(": ");
            v.render(out);
        }
        out.push('}');
    }

    pub fn to_json(&self) -> String {
        let mut out = String::new();
        self.render(&mut out);
        out
    }
}

/// Render records as a JSON array, one record per line (diff-friendly and
/// trivially `json.load`-able).
pub fn render_records(records: &[Record]) -> String {
    let mut out = String::from("[\n");
    for (i, r) in records.iter().enumerate() {
        out.push_str("  ");
        r.render(&mut out);
        out.push_str(if i + 1 == records.len() { "\n" } else { ",\n" });
    }
    out.push_str("]\n");
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn series_tail_mean_and_threshold() {
        let mut s = Series::new("loss");
        for i in 0..100 {
            s.push(i as f64, 100.0 - i as f64);
        }
        assert!(s.tail_mean(0.1) < 10.0);
        assert_eq!(s.first_time_below(50.0), Some(51.0));
        assert_eq!(s.first_time_below(-1.0), None);
    }

    #[test]
    fn downsample_bounds() {
        let mut s = Series::new("x");
        for i in 0..1000 {
            s.push(i as f64, 0.0);
        }
        let d = s.downsample(100);
        assert!(d.points.len() <= 100);
        assert_eq!(d.points[0].0, 0.0);
    }

    #[test]
    fn stats_known_values() {
        let st = Stats::of(&[1.0, 2.0, 3.0, 4.0]);
        assert_eq!(st.n, 4);
        assert!((st.mean - 2.5).abs() < 1e-12);
        assert!((st.std - (5.0f64 / 3.0).sqrt()).abs() < 1e-12);
        assert_eq!(st.min, 1.0);
        assert_eq!(st.max, 4.0);
        assert_eq!(st.pm(1), "2.5±1.3");
    }

    #[test]
    fn quantile_interpolates() {
        let v = [1.0, 2.0, 3.0, 4.0, 5.0];
        assert_eq!(quantile(&v, 0.0), 1.0);
        assert_eq!(quantile(&v, 1.0), 5.0);
        assert_eq!(quantile(&v, 0.5), 3.0);
        assert_eq!(quantile(&v, 0.25), 2.0);
    }

    #[test]
    fn csv_round_trip() {
        let mut r = Recorder::new();
        r.record("a", 0.0, 1.0);
        r.record("a", 1.0, 2.0);
        r.record("b", 0.5, -1.0);
        let path = std::env::temp_dir().join("a2cid2_test_metrics.csv");
        r.write_csv(&path, 1000).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        assert!(text.starts_with("series,t,value\n"));
        assert!(text.contains("a,0,1"));
        assert!(text.contains("b,0.5,-1"));
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn table_renders_aligned() {
        let mut t = Table::new("demo", &["col", "value"]);
        t.row(&["a".into(), "1".into()]);
        t.row(&["long-name".into(), "2".into()]);
        let r = t.render();
        assert!(r.contains("## demo"));
        assert!(r.contains("| long-name |"));
    }

    #[test]
    fn record_json_rendering() {
        let rec = Record::new()
            .str("id", "fig\"1\"")
            .f64("loss", 1.25)
            .f64("nan", f64::NAN)
            .u64("n", 16)
            .bool("ok", true)
            .opt_u64("missing", None)
            .records("rows", vec![Record::new().f64("x", 0.5)]);
        let json = rec.to_json();
        assert_eq!(
            json,
            "{\"id\": \"fig\\\"1\\\"\", \"loss\": 1.25, \"nan\": null, \"n\": 16, \
             \"ok\": true, \"missing\": null, \"rows\": [{\"x\": 0.5}]}"
        );
        assert_eq!(rec.get("n"), Some(&Value::U64(16)));
        assert!(rec.get("nope").is_none());
    }

    #[test]
    fn render_records_is_an_array() {
        let rows = vec![Record::new().u64("a", 1), Record::new().u64("a", 2)];
        let text = render_records(&rows);
        assert!(text.starts_with("[\n"));
        assert!(text.ends_with("]\n"));
        assert!(text.contains("{\"a\": 1},\n"));
        assert!(text.contains("{\"a\": 2}\n"));
        assert_eq!(render_records(&[]), "[\n]\n");
    }

    #[test]
    fn table_bridges_to_typed_records() {
        let mut t = Table::new("demo", &["variant", "final loss"]);
        t.row(&["ring / A2CiD2".into(), "1.25".into()]);
        t.row(&["baseline".into(), "never".into()]);
        let recs = t.to_records();
        assert_eq!(recs.len(), 2);
        assert_eq!(recs[0].get("table"), Some(&Value::Str("demo".into())));
        assert_eq!(recs[0].get("final loss"), Some(&Value::F64(1.25)));
        assert_eq!(recs[1].get("final loss"), Some(&Value::Str("never".into())));
    }
}
