//! Small shared utilities with no better home.

/// Disjoint pair of mutable references into one slice — the safe way to
/// hand both endpoints of a pairwise communication event to the fused
/// kernels. Panics if `i == j` or either index is out of bounds.
pub fn two_mut<T>(slice: &mut [T], i: usize, j: usize) -> (&mut T, &mut T) {
    assert!(i != j, "two_mut needs distinct indices, got {i} twice");
    if i < j {
        let (l, r) = slice.split_at_mut(j);
        (&mut l[i], &mut r[0])
    } else {
        let (l, r) = slice.split_at_mut(i);
        (&mut r[0], &mut l[j])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn returns_both_orders() {
        let mut v = vec![10, 20, 30, 40];
        {
            let (a, b) = two_mut(&mut v, 1, 3);
            assert_eq!((*a, *b), (20, 40));
            *a = 2;
            *b = 4;
        }
        {
            let (a, b) = two_mut(&mut v, 3, 0);
            assert_eq!((*a, *b), (4, 10));
        }
        assert_eq!(v, vec![10, 2, 30, 4]);
    }

    #[test]
    #[should_panic(expected = "distinct indices")]
    fn rejects_equal_indices() {
        let mut v = vec![1, 2];
        let _ = two_mut(&mut v, 1, 1);
    }
}
