"""L2 model checks: shapes, losses, gradients, and the fused train step."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M
from compile.kernels import ref


def test_mlp_param_dim():
    spec = M.MlpSpec(dim=32, hidden=64, n_classes=10)
    assert spec.param_spec().dim == 64 * 32 + 64 + 10 * 64 + 10


def test_param_spec_round_trip():
    spec = M.MlpSpec().param_spec()
    flat = jnp.arange(spec.dim, dtype=jnp.float32)
    tree = spec.unflatten(flat)
    back = spec.flatten(tree)
    np.testing.assert_array_equal(np.asarray(back), np.asarray(flat))


def test_mlp_initial_loss_is_log_k():
    spec = M.MlpSpec()
    flat = spec.init(0)
    # Head starts near zero -> logits near-uniform -> loss ~= log(K).
    rng = np.random.default_rng(0)
    xb = jnp.asarray(rng.standard_normal((spec.batch, spec.dim)), jnp.float32)
    yb = jnp.asarray(rng.integers(0, spec.n_classes, spec.batch), jnp.int32)
    loss = spec.loss(flat, xb, yb)
    assert float(loss) == pytest.approx(np.log(spec.n_classes), rel=0.2)


def test_mlp_gradient_descends():
    spec = M.MlpSpec(dim=8, hidden=16, n_classes=4, batch=32)
    flat = spec.init(1)
    rng = np.random.default_rng(1)
    xb = jnp.asarray(rng.standard_normal((32, 8)), jnp.float32)
    yb = jnp.asarray(rng.integers(0, 4, 32), jnp.int32)
    grad_fn = jax.jit(jax.value_and_grad(spec.loss))
    l0, _ = grad_fn(flat, xb, yb)
    for _ in range(50):
        _, g = grad_fn(flat, xb, yb)
        flat = flat - 0.5 * g
    l1, _ = grad_fn(flat, xb, yb)
    assert float(l1) < 0.5 * float(l0)


def test_transformer_shapes_and_loss():
    spec = M.TransformerSpec.preset("tiny")
    flat = spec.init(0)
    assert flat.shape == (spec.param_spec().dim,)
    rng = np.random.default_rng(2)
    toks = jnp.asarray(rng.integers(0, spec.vocab, (spec.batch, spec.seq)), jnp.int32)
    tgts = jnp.asarray(rng.integers(0, spec.vocab, (spec.batch, spec.seq)), jnp.int32)
    loss = spec.loss(flat, toks, tgts)
    # Random targets -> about log(vocab).
    assert float(loss) == pytest.approx(np.log(spec.vocab), rel=0.25)


def test_transformer_causality():
    # Changing a future token must not change earlier positions' loss
    # contributions: compare per-position NLL directly via logits trick --
    # here we check that prefix loss is unchanged when the tail changes.
    spec = M.TransformerSpec.preset("tiny")
    flat = spec.init(3)
    rng = np.random.default_rng(3)
    toks = np.asarray(rng.integers(0, spec.vocab, (1, spec.seq)), np.int32)
    toks2 = toks.copy()
    toks2[0, -1] = (toks2[0, -1] + 1) % spec.vocab

    def prefix_loss(tokens):
        # Loss restricted to the first half of positions.
        p = spec.param_spec()
        tgt = np.roll(tokens, -1, axis=1)
        full = spec.loss(flat, jnp.asarray(tokens), jnp.asarray(tgt))
        del p, full
        # Recompute with masked mean over first half only, via vmap-free
        # trick: zero out tail targets' contribution by comparing two
        # sums is overkill here; instead check logits prefix equality.
        return None

    # Direct: logits over the prefix must be identical.
    # (Reuse the internal forward by calling loss with equal targets and
    # verifying the total only differs through the final position.)
    t_same = jnp.asarray(np.roll(toks, -1, axis=1))
    l1 = spec.loss(flat, jnp.asarray(toks), t_same)
    l2 = spec.loss(flat, jnp.asarray(toks2), t_same)
    # Only the last input token differs; with causal masking it can only
    # influence the last position's prediction: per-position mean over S
    # positions bounds the difference by ~(max nll)/S, not zero, so assert
    # a loose bound instead of equality.
    assert abs(float(l1) - float(l2)) < np.log(spec.vocab) * 2.0 / spec.seq + 0.1


def test_transformer_learns_constant_sequence():
    spec = M.TransformerSpec(vocab=16, d_model=32, n_layers=1, n_heads=2, seq=8, batch=4)
    flat = spec.init(4)
    toks = jnp.ones((spec.batch, spec.seq), jnp.int32) * 3
    tgts = toks
    grad_fn = jax.jit(jax.value_and_grad(spec.loss))
    for _ in range(60):
        _, g = grad_fn(flat, toks, tgts)
        flat = flat - 0.5 * g
    loss, _ = grad_fn(flat, toks, tgts)
    assert float(loss) < 0.1


def test_train_step_composes_l1_and_l2():
    spec = M.MlpSpec(dim=8, hidden=16, n_classes=4, batch=8)
    step = jax.jit(M.make_train_step(spec))
    flat = spec.init(5)
    xt = flat + 0.05
    rng = np.random.default_rng(5)
    xb = jnp.asarray(rng.standard_normal((8, 8)), jnp.float32)
    yb = jnp.asarray(rng.integers(0, 4, 8), jnp.int32)
    eta, dt, lr = 0.3, 0.6, 0.1
    new_x, new_xt, loss = step(flat, xt, xb, yb, eta, dt, lr)
    # Oracle: grad from value_and_grad + ref.mix_grad.
    l_ref, g = jax.value_and_grad(spec.loss)(flat, xb, yb)
    want_x, want_xt = ref.mix_grad(flat, xt, g, eta, dt, lr)
    assert float(loss) == pytest.approx(float(l_ref), abs=1e-6)
    np.testing.assert_allclose(np.asarray(new_x), np.asarray(want_x), atol=1e-5)
    np.testing.assert_allclose(np.asarray(new_xt), np.asarray(want_xt), atol=1e-5)


def test_presets():
    tiny = M.TransformerSpec.preset("tiny")
    small = M.TransformerSpec.preset("small")
    assert tiny.param_spec().dim < small.param_spec().dim
    paper = M.TransformerSpec.preset("paper")
    assert paper.param_spec().dim > 80_000_000, "paper preset ~100M params"
    with pytest.raises(ValueError):
        M.TransformerSpec.preset("nope")
