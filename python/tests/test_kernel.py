"""Pallas kernel vs pure-jnp oracle: the core L1 correctness signal.

Hypothesis sweeps shapes and event parameters; fixed cases pin the
closed-form math (doubly-stochastic weights, mass conservation, baseline
reductions).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import acid_mix, ref

# Sizes that exercise: sub-block, exact block, multi-block, ragged tail.
SIZES = [1, 7, 4096, 8192, 10_000]


def rand_vec(rng, n):
    return jnp.asarray(rng.standard_normal(n), jnp.float32)


@pytest.mark.parametrize("n", SIZES)
def test_mix_grad_matches_ref(n):
    rng = np.random.default_rng(n)
    x, xt, g = (rand_vec(rng, n) for _ in range(3))
    out = acid_mix.mix_grad(x, xt, g, 0.25, 0.8, 0.05)
    want = ref.mix_grad(x, xt, g, 0.25, 0.8, 0.05)
    np.testing.assert_allclose(out[0], want[0], atol=1e-6)
    np.testing.assert_allclose(out[1], want[1], atol=1e-6)


@pytest.mark.parametrize("n", SIZES)
def test_mix_comm_matches_ref(n):
    rng = np.random.default_rng(100 + n)
    x, xt, xp = (rand_vec(rng, n) for _ in range(3))
    out = acid_mix.mix_comm(x, xt, xp, 0.25, 0.8, 0.5, 1.7)
    want = ref.mix_comm(x, xt, xp, 0.25, 0.8, 0.5, 1.7)
    np.testing.assert_allclose(out[0], want[0], atol=1e-6)
    np.testing.assert_allclose(out[1], want[1], atol=1e-6)


@settings(max_examples=40, deadline=None)
@given(
    n=st.integers(min_value=1, max_value=9000),
    eta=st.floats(min_value=0.0, max_value=5.0),
    dt=st.floats(min_value=0.0, max_value=10.0),
    gamma=st.floats(min_value=0.0, max_value=1.0),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_mix_grad_hypothesis(n, eta, dt, gamma, seed):
    rng = np.random.default_rng(seed)
    x, xt, g = (rand_vec(rng, n) for _ in range(3))
    out = acid_mix.mix_grad(x, xt, g, eta, dt, gamma)
    want = ref.mix_grad(x, xt, g, eta, dt, gamma)
    np.testing.assert_allclose(out[0], want[0], atol=1e-5)
    np.testing.assert_allclose(out[1], want[1], atol=1e-5)


@settings(max_examples=40, deadline=None)
@given(
    n=st.integers(min_value=1, max_value=9000),
    eta=st.floats(min_value=0.0, max_value=5.0),
    dt=st.floats(min_value=0.0, max_value=10.0),
    alpha=st.floats(min_value=0.0, max_value=1.0),
    alpha_tilde=st.floats(min_value=0.0, max_value=8.0),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_mix_comm_hypothesis(n, eta, dt, alpha, alpha_tilde, seed):
    rng = np.random.default_rng(seed)
    x, xt, xp = (rand_vec(rng, n) for _ in range(3))
    out = acid_mix.mix_comm(x, xt, xp, eta, dt, alpha, alpha_tilde)
    want = ref.mix_comm(x, xt, xp, eta, dt, alpha, alpha_tilde)
    np.testing.assert_allclose(out[0], want[0], atol=1e-5)
    np.testing.assert_allclose(out[1], want[1], atol=1e-5)


def test_mixing_weights_doubly_stochastic():
    for eta in [0.0, 0.1, 2.0]:
        for dt in [0.0, 0.5, 100.0]:
            wa, wb = ref.mix_weights(eta, dt)
            assert float(wa + wb) == pytest.approx(1.0, abs=1e-6)
            assert float(wa) >= 0.5 - 1e-6


def test_mass_conservation():
    rng = np.random.default_rng(0)
    x, xt = rand_vec(rng, 5000), rand_vec(rng, 5000)
    mx, mxt = ref.mix(x, xt, 0.7, 0.3)
    np.testing.assert_allclose(np.asarray(mx + mxt), np.asarray(x + xt), atol=1e-5)


def test_eta_zero_is_identity_mixing():
    rng = np.random.default_rng(1)
    x, xt, g = (rand_vec(rng, 4096) for _ in range(3))
    ox, oxt = acid_mix.mix_grad(x, xt, g, 0.0, 5.0, 0.1)
    np.testing.assert_allclose(np.asarray(ox), np.asarray(x - 0.1 * g), atol=1e-6)
    np.testing.assert_allclose(np.asarray(oxt), np.asarray(xt - 0.1 * g), atol=1e-6)


def test_baseline_comm_is_averaging():
    # alpha = alpha_tilde = 1/2, eta = 0, xt == x: both rows land on the
    # pairwise average (Eq. 6).
    rng = np.random.default_rng(2)
    x = rand_vec(rng, 4096)
    xp = rand_vec(rng, 4096)
    ox, oxt = acid_mix.mix_comm(x, x, xp, 0.0, 1.0, 0.5, 0.5)
    np.testing.assert_allclose(np.asarray(ox), np.asarray(0.5 * (x + xp)), atol=1e-6)
    np.testing.assert_allclose(np.asarray(oxt), np.asarray(ox), atol=1e-6)


def test_semigroup_two_small_steps_equal_one_big():
    rng = np.random.default_rng(3)
    x, xt = rand_vec(rng, 2048), rand_vec(rng, 2048)
    a1, b1 = ref.mix(x, xt, 0.4, 0.25)
    a1, b1 = ref.mix(a1, b1, 0.4, 0.75)
    a2, b2 = ref.mix(x, xt, 0.4, 1.0)
    np.testing.assert_allclose(np.asarray(a1), np.asarray(a2), atol=1e-5)
    np.testing.assert_allclose(np.asarray(b1), np.asarray(b2), atol=1e-5)


def test_kernel_jit_composes():
    # The kernel must lower inside a jitted graph (the aot.py path).
    @jax.jit
    def step(x, xt, g):
        return acid_mix.mix_grad(x, xt, g, 0.2, 0.5, 0.1)

    rng = np.random.default_rng(4)
    x, xt, g = (rand_vec(rng, 4096) for _ in range(3))
    out = step(x, xt, g)
    want = ref.mix_grad(x, xt, g, 0.2, 0.5, 0.1)
    np.testing.assert_allclose(out[0], want[0], atol=1e-6)
