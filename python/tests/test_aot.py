"""AOT pipeline checks: HLO-text lowering and the manifest contract."""

import os

import jax
import jax.numpy as jnp
import pytest

from compile import aot
from compile import model as M


def test_to_hlo_text_emits_parseable_module():
    lowered = jax.jit(lambda x, y: (x @ y + 2.0,)).lower(
        jax.ShapeDtypeStruct((2, 2), jnp.float32),
        jax.ShapeDtypeStruct((2, 2), jnp.float32),
    )
    text = aot.to_hlo_text(lowered)
    # HLO text module header + a root tuple (return_tuple=True).
    assert text.startswith("HloModule")
    assert "ROOT" in text
    assert "tuple" in text


def test_manifest_format_round_trips(tmp_path):
    man = aot.Manifest()
    man.add("foo", file="foo.hlo.txt", kind="grad", param_dim=7)
    man.add("bar", file="bar.bin", kind="init", param_dim=7, seed=3)
    man.write(str(tmp_path))
    lines = (tmp_path / "manifest.txt").read_text().strip().splitlines()
    assert lines[0].startswith("#")
    assert lines[1] == "foo file=foo.hlo.txt kind=grad param_dim=7"
    assert lines[2] == "bar file=bar.bin kind=init param_dim=7 seed=3"


def test_emit_model_writes_all_artifacts(tmp_path):
    man = aot.Manifest()
    spec = M.MlpSpec(dim=4, hidden=8, n_classes=3, batch=4)
    aot.emit_model(str(tmp_path), man, spec, seed=0)
    names = {line.split()[0] for line in man.lines}
    assert names == {
        "mlp_init",
        "mlp_train_step",
        "mlp_grad",
        "mlp_eval",
        "mlp_comm_step",
    }
    for line in man.lines:
        fname = dict(kv.split("=") for kv in line.split()[1:])["file"]
        path = tmp_path / fname
        assert path.exists(), fname
        assert path.stat().st_size > 0
    # Init blob is exactly param_dim f32s.
    dim = spec.param_spec().dim
    assert (tmp_path / "mlp_init.bin").stat().st_size == 4 * dim


def test_train_step_hlo_has_expected_parameter_count(tmp_path):
    spec = M.MlpSpec(dim=4, hidden=8, n_classes=3, batch=4)
    dim = spec.param_spec().dim
    lowered = jax.jit(M.make_train_step(spec)).lower(
        aot.vec(dim),
        aot.vec(dim),
        *spec.batch_shapes(),
        aot.scalar(),
        aot.scalar(),
        aot.scalar(),
    )
    text = aot.to_hlo_text(lowered)
    # 7 inputs: x, xt, batch_a, batch_b, eta, dt, lr.
    assert "parameter(6)" in text
    assert "parameter(7)" not in text


def test_paper_preset_guarded_from_accidental_build(tmp_path):
    # The paper preset is ~100M params; verify we can *spec* it without
    # materializing (init would allocate ~400 MB — not done here).
    spec = M.TransformerSpec.preset("paper")
    assert spec.param_spec().dim > 80_000_000
