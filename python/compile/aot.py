"""AOT lowering: JAX -> HLO text artifacts for the Rust runtime.

Interchange format is HLO **text**, not serialized HloModuleProto: jax
>= 0.5 emits protos with 64-bit instruction ids which the xla crate's
xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Artifacts (``python -m compile.aot --out ../artifacts``):

  <model>_train_step.hlo.txt  (x, xt, batch_a, batch_b, eta, dt, lr)
                              -> (new_x, new_xt, loss)
  <model>_grad.hlo.txt        (x, batch_a, batch_b) -> (loss, grad)
  <model>_eval.hlo.txt        (x, batch_a, batch_b) -> (loss,)
  <model>_comm_step.hlo.txt   (x, xt, x_peer, eta, dt, alpha, alpha_t)
                              -> (new_x, new_xt)
  <model>_init.bin            raw little-endian f32[P] initial parameters
  acid_mix_grad_<N>.hlo.txt   standalone fused kernel (tests/perf)
  acid_mix_comm_<N>.hlo.txt   standalone fused kernel (tests/perf)
  manifest.txt                one artifact per line: name + key=value

Python runs ONCE at build time; `make artifacts` is a no-op afterwards.
"""

import argparse
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model as M

F32 = jnp.float32
I32 = jnp.int32


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def scalar():
    return jax.ShapeDtypeStruct((), F32)


def vec(n):
    return jax.ShapeDtypeStruct((n,), F32)


class Manifest:
    def __init__(self):
        self.lines = []

    def add(self, name, **kv):
        fields = " ".join(f"{k}={v}" for k, v in kv.items())
        self.lines.append(f"{name} {fields}")

    def write(self, outdir):
        with open(os.path.join(outdir, "manifest.txt"), "w") as f:
            f.write("# a2cid2 artifact manifest: <name> key=value...\n")
            f.write("\n".join(self.lines) + "\n")


def emit(outdir, manifest, name, fn, args, **meta):
    lowered = jax.jit(fn).lower(*args)
    text = to_hlo_text(lowered)
    fname = f"{name}.hlo.txt"
    with open(os.path.join(outdir, fname), "w") as f:
        f.write(text)
    manifest.add(name, file=fname, **meta)
    print(f"  {fname}  ({len(text) / 1024:.0f} KiB)")


def emit_model(outdir, manifest, spec, seed):
    dim = spec.param_spec().dim
    ba, bb = spec.batch_shapes()
    name = spec.name
    print(f"[{name}] P={dim}")

    # Initial parameters as raw bytes (one consensus init for all workers).
    init = spec.init(seed)
    init_file = f"{name}_init.bin"
    with open(os.path.join(outdir, init_file), "wb") as f:
        f.write(bytes(memoryview(jax.device_get(init).astype("float32"))))
    manifest.add(
        f"{name}_init",
        file=init_file,
        kind="init",
        model=name,
        param_dim=dim,
        seed=seed,
    )

    common = dict(model=name, param_dim=dim)
    if name == "mlp":
        common.update(
            feat_dim=spec.dim, n_classes=spec.n_classes, batch=spec.batch
        )
    else:
        common.update(
            vocab=spec.vocab,
            seq=spec.seq,
            batch=spec.batch,
            d_model=spec.d_model,
            n_layers=spec.n_layers,
            n_heads=spec.n_heads,
        )

    emit(
        outdir,
        manifest,
        f"{name}_train_step",
        M.make_train_step(spec),
        (vec(dim), vec(dim), ba, bb, scalar(), scalar(), scalar()),
        kind="train_step",
        **common,
    )
    emit(
        outdir,
        manifest,
        f"{name}_grad",
        M.make_grad_only(spec),
        (vec(dim), ba, bb),
        kind="grad",
        **common,
    )
    emit(
        outdir,
        manifest,
        f"{name}_eval",
        M.make_eval_loss(spec),
        (vec(dim), ba, bb),
        kind="eval",
        **common,
    )
    emit(
        outdir,
        manifest,
        f"{name}_comm_step",
        M.make_comm_step(dim),
        (vec(dim), vec(dim), vec(dim), scalar(), scalar(), scalar(), scalar()),
        kind="comm_step",
        **common,
    )


def main():
    parser = argparse.ArgumentParser(description=__doc__)
    parser.add_argument("--out", default="../artifacts")
    parser.add_argument("--seed", type=int, default=0)
    parser.add_argument(
        "--transformer-preset",
        default=os.environ.get("A2CID2_TRANSFORMER_PRESET", "small"),
        help="tiny | small | medium | paper (~100M params)",
    )
    parser.add_argument(
        "--kernel-sizes",
        default="4096,65536",
        help="comma-separated standalone-kernel sizes",
    )
    args = parser.parse_args()
    os.makedirs(args.out, exist_ok=True)
    manifest = Manifest()

    emit_model(args.out, manifest, M.MlpSpec(), args.seed)
    emit_model(
        args.out, manifest, M.TransformerSpec.preset(args.transformer_preset), args.seed
    )

    for n in [int(s) for s in args.kernel_sizes.split(",") if s]:
        emit(
            args.out,
            manifest,
            f"acid_mix_grad_{n}",
            M.make_mix_grad(n),
            (vec(n), vec(n), vec(n), scalar(), scalar(), scalar()),
            kind="kernel_grad",
            param_dim=n,
        )
        emit(
            args.out,
            manifest,
            f"acid_mix_comm_{n}",
            M.make_comm_step(n),
            (vec(n), vec(n), vec(n), scalar(), scalar(), scalar(), scalar()),
            kind="kernel_comm",
            param_dim=n,
        )

    manifest.write(args.out)
    print(f"manifest: {len(manifest.lines)} artifacts -> {args.out}/manifest.txt")


if __name__ == "__main__":
    main()
