"""Layer-2 JAX models over flat parameter vectors.

The Rust coordinator treats a model replica as an opaque ``f32[P]`` vector
(that is what the gossip layer averages), so every model here exposes:

* ``init(seed) -> f32[P]`` — parameter initialization (run once at build
  time; the bytes are shipped in ``artifacts/<model>_init.bin``);
* ``train_step(x, xt, batch..., eta, dt, lr) -> (new_x, new_xt, loss)`` —
  the request-path gradient event: fwd/bwd on the mini-batch, then the
  fused L1 Pallas kernel applies the A2CiD2 mixing + SGD step to both
  rows (Algorithm 1, lines 6-12);
* ``comm_step(x, xt, x_peer, eta, dt, alpha, alpha_tilde)`` — the p2p
  averaging event via the fused kernel (lines 13-19).

Both are lowered ONCE to HLO text by ``aot.py``; Python never runs on the
request path.

Models:
* ``MlpSpec``   — `dim -> hidden -> classes` ReLU classifier (the
  CIFAR-like workload).
* ``TransformerSpec`` — pre-LN causal transformer LM (the end-to-end
  driver). ``preset="paper"`` builds a ~100M-parameter configuration; the
  recorded e2e run uses a smaller preset sized for this CPU image
  (EXPERIMENTS.md notes the substitution).
"""

import dataclasses
from typing import List, Tuple

import jax
import jax.numpy as jnp

from .kernels import acid_mix

# --------------------------------------------------------------------------
# Flat-parameter plumbing
# --------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ParamSpec:
    """Named shapes making up the flat vector, in order."""

    entries: Tuple[Tuple[str, Tuple[int, ...]], ...]

    @property
    def dim(self) -> int:
        total = 0
        for _, shape in self.entries:
            size = 1
            for s in shape:
                size *= s
            total += size
        return total

    def unflatten(self, flat):
        """Slice the flat vector into a dict of named arrays."""
        out = {}
        offset = 0
        for name, shape in self.entries:
            size = 1
            for s in shape:
                size *= s
            out[name] = flat[offset : offset + size].reshape(shape)
            offset += size
        return out

    def flatten(self, tree) -> jnp.ndarray:
        return jnp.concatenate([tree[name].reshape(-1) for name, _ in self.entries])


# --------------------------------------------------------------------------
# MLP classifier
# --------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class MlpSpec:
    dim: int = 32
    hidden: int = 64
    n_classes: int = 10
    batch: int = 16

    @property
    def name(self) -> str:
        return "mlp"

    def param_spec(self) -> ParamSpec:
        return ParamSpec(
            (
                ("w1", (self.hidden, self.dim)),
                ("b1", (self.hidden,)),
                ("w2", (self.n_classes, self.hidden)),
                ("b2", (self.n_classes,)),
            )
        )

    def init(self, seed: int) -> jnp.ndarray:
        spec = self.param_spec()
        k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
        tree = {
            "w1": jax.random.normal(k1, (self.hidden, self.dim), jnp.float32)
            * jnp.sqrt(2.0 / self.dim),
            "b1": jnp.zeros((self.hidden,), jnp.float32),
            "w2": jax.random.normal(k2, (self.n_classes, self.hidden), jnp.float32)
            * jnp.sqrt(1.0 / self.hidden),
            "b2": jnp.zeros((self.n_classes,), jnp.float32),
        }
        return spec.flatten(tree)

    def loss(self, flat, xb, yb):
        """Mean softmax cross-entropy on a (B, dim) batch."""
        p = self.param_spec().unflatten(flat)
        h = jnp.maximum(xb @ p["w1"].T + p["b1"], 0.0)
        logits = h @ p["w2"].T + p["b2"]
        logp = jax.nn.log_softmax(logits, axis=-1)
        return -jnp.mean(jnp.take_along_axis(logp, yb[:, None], axis=-1))

    def batch_shapes(self):
        return (
            jax.ShapeDtypeStruct((self.batch, self.dim), jnp.float32),
            jax.ShapeDtypeStruct((self.batch,), jnp.int32),
        )


# --------------------------------------------------------------------------
# Transformer LM
# --------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class TransformerSpec:
    vocab: int = 256
    d_model: int = 128
    n_layers: int = 4
    n_heads: int = 4
    seq: int = 64
    batch: int = 8

    @classmethod
    def preset(cls, name: str) -> "TransformerSpec":
        """Named sizes: tiny (tests), small (e2e driver), paper (~100M)."""
        if name == "tiny":
            return cls(vocab=64, d_model=32, n_layers=2, n_heads=2, seq=16, batch=4)
        if name == "small":
            return cls(vocab=256, d_model=128, n_layers=4, n_heads=4, seq=64, batch=8)
        if name == "medium":
            return cls(vocab=512, d_model=256, n_layers=6, n_heads=8, seq=128, batch=8)
        if name == "paper":
            # ~100M parameters: 12 x 768 with a 32k vocabulary.
            return cls(vocab=32768, d_model=768, n_layers=12, n_heads=12, seq=256, batch=8)
        raise ValueError(f"unknown preset '{name}'")

    @property
    def name(self) -> str:
        return "transformer"

    @property
    def d_head(self) -> int:
        assert self.d_model % self.n_heads == 0
        return self.d_model // self.n_heads

    @property
    def d_ff(self) -> int:
        return 4 * self.d_model

    def param_spec(self) -> ParamSpec:
        entries: List[Tuple[str, Tuple[int, ...]]] = [
            ("tok_emb", (self.vocab, self.d_model)),
            ("pos_emb", (self.seq, self.d_model)),
        ]
        for layer in range(self.n_layers):
            p = f"l{layer}."
            entries += [
                (p + "ln1_g", (self.d_model,)),
                (p + "ln1_b", (self.d_model,)),
                (p + "wqkv", (self.d_model, 3 * self.d_model)),
                (p + "wo", (self.d_model, self.d_model)),
                (p + "ln2_g", (self.d_model,)),
                (p + "ln2_b", (self.d_model,)),
                (p + "w_ff1", (self.d_model, self.d_ff)),
                (p + "b_ff1", (self.d_ff,)),
                (p + "w_ff2", (self.d_ff, self.d_model)),
                (p + "b_ff2", (self.d_model,)),
            ]
        entries += [
            ("lnf_g", (self.d_model,)),
            ("lnf_b", (self.d_model,)),
            ("head", (self.d_model, self.vocab)),
        ]
        return ParamSpec(tuple(entries))

    def init(self, seed: int) -> jnp.ndarray:
        spec = self.param_spec()
        key = jax.random.PRNGKey(seed)
        tree = {}
        for name, shape in spec.entries:
            key, sub = jax.random.split(key)
            if name.endswith(("_g",)):
                tree[name] = jnp.ones(shape, jnp.float32)
            elif name.endswith(("_b", "ln1_b", "ln2_b", "lnf_b")) or name.startswith("b_"):
                tree[name] = jnp.zeros(shape, jnp.float32)
            elif len(shape) == 1:
                tree[name] = jnp.zeros(shape, jnp.float32)
            else:
                fan_in = shape[0]
                std = 0.02 if "emb" in name else (1.0 / fan_in) ** 0.5
                tree[name] = jax.random.normal(sub, shape, jnp.float32) * std
        return spec.flatten(tree)

    @staticmethod
    def _layer_norm(x, g, b):
        mu = jnp.mean(x, axis=-1, keepdims=True)
        var = jnp.var(x, axis=-1, keepdims=True)
        return (x - mu) / jnp.sqrt(var + 1e-5) * g + b

    def loss(self, flat, tokens, targets):
        """Mean next-token cross-entropy on (B, S) int32 token batches."""
        p = self.param_spec().unflatten(flat)
        B, S = tokens.shape
        h = p["tok_emb"][tokens] + p["pos_emb"][None, :S, :]
        mask = jnp.tril(jnp.ones((S, S), jnp.float32))
        neg_inf = jnp.float32(-1e9)
        for layer in range(self.n_layers):
            pref = f"l{layer}."
            x = self._layer_norm(h, p[pref + "ln1_g"], p[pref + "ln1_b"])
            qkv = x @ p[pref + "wqkv"]  # (B,S,3D)
            q, k, v = jnp.split(qkv, 3, axis=-1)
            q = q.reshape(B, S, self.n_heads, self.d_head).transpose(0, 2, 1, 3)
            k = k.reshape(B, S, self.n_heads, self.d_head).transpose(0, 2, 1, 3)
            v = v.reshape(B, S, self.n_heads, self.d_head).transpose(0, 2, 1, 3)
            att = jnp.einsum("bhqd,bhkd->bhqk", q, k) / jnp.sqrt(
                jnp.float32(self.d_head)
            )
            att = jnp.where(mask[None, None] > 0, att, neg_inf)
            att = jax.nn.softmax(att, axis=-1)
            out = jnp.einsum("bhqk,bhkd->bhqd", att, v)
            out = out.transpose(0, 2, 1, 3).reshape(B, S, self.d_model)
            h = h + out @ p[pref + "wo"]
            x = self._layer_norm(h, p[pref + "ln2_g"], p[pref + "ln2_b"])
            ff = jax.nn.gelu(x @ p[pref + "w_ff1"] + p[pref + "b_ff1"])
            h = h + ff @ p[pref + "w_ff2"] + p[pref + "b_ff2"]
        h = self._layer_norm(h, p["lnf_g"], p["lnf_b"])
        logits = h @ p["head"]
        logp = jax.nn.log_softmax(logits, axis=-1)
        nll = -jnp.take_along_axis(logp, targets[..., None], axis=-1)
        return jnp.mean(nll)

    def batch_shapes(self):
        return (
            jax.ShapeDtypeStruct((self.batch, self.seq), jnp.int32),
            jax.ShapeDtypeStruct((self.batch, self.seq), jnp.int32),
        )


# --------------------------------------------------------------------------
# Request-path step functions (lowered to HLO by aot.py)
# --------------------------------------------------------------------------


def make_train_step(spec):
    """(x, xt, batch_a, batch_b, eta, dt, lr) -> (new_x, new_xt, loss).

    fwd/bwd through the model (L2) and the fused A2CiD2 mixing + SGD step
    through the Pallas kernel (L1), all in one HLO module. Heavy-ball
    momentum on the gradient is folded on the Rust side (it owns the
    velocity buffer); here ``lr`` multiplies the raw batch gradient.
    """

    def step(x, xt, batch_a, batch_b, eta, dt, lr):
        loss, grad = jax.value_and_grad(spec.loss)(x, batch_a, batch_b)
        new_x, new_xt = acid_mix.mix_grad(x, xt, grad, eta, dt, lr)
        return new_x, new_xt, loss

    return step


def make_grad_only(spec):
    """(x, batch_a, batch_b) -> (loss, grad): for the Rust-side optimizer
    path (heavy-ball momentum folds the gradient before the mixing kernel
    is applied via the comm/grad artifacts)."""

    def fn(x, batch_a, batch_b):
        loss, grad = jax.value_and_grad(spec.loss)(x, batch_a, batch_b)
        return loss, grad

    return fn


def make_eval_loss(spec):
    """(x, batch_a, batch_b) -> loss, no gradient (validation pass)."""

    def fn(x, batch_a, batch_b):
        return (spec.loss(x, batch_a, batch_b),)

    return fn


def make_comm_step(dim: int):
    """(x, xt, x_peer, eta, dt, alpha, alpha_tilde) -> (new_x, new_xt)."""

    def step(x, xt, x_peer, eta, dt, alpha, alpha_tilde):
        return acid_mix.mix_comm(x, xt, x_peer, eta, dt, alpha, alpha_tilde)

    return step


def make_mix_grad(dim: int):
    """Standalone fused kernel artifact (tests + perf bench):
    (x, xt, g, eta, dt, gamma) -> (new_x, new_xt)."""

    def step(x, xt, g, eta, dt, gamma):
        return acid_mix.mix_grad(x, xt, g, eta, dt, gamma)

    return step
