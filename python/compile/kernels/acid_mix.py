"""Layer-1 Pallas kernels: the fused A2CiD2 mixing + update hot-spot.

Every gossip event touches the full flat parameter vector. Done naively
that is a chain of BLAS-1 passes (mix x, mix x~, subtract step / form m,
apply to x, apply to x~): 5+ reads and writes of each element. These
kernels fuse each event into a single pass — for P parameters:

* ``mix_grad``:  3 reads (x, x~, g) + 2 writes per element;
* ``mix_comm``:  3 reads (x, x~, x_peer) + 2 writes per element.

TPU adaptation (DESIGN.md §Hardware-Adaptation): the kernel is element-wise
and memory-bound, so instead of GPU threadblocks the flat vector is tiled
into VMEM-sized blocks with a 1-D grid; ``BlockSpec`` expresses the
HBM->VMEM pipeline. The scalar event parameters (dt, eta, gamma, alphas)
ride along as a tiny SMEM-resident operand block replicated to every grid
step. There is no MXU work here; the roofline is bytes/s.

``interpret=True`` everywhere: the CPU PJRT plugin cannot execute Mosaic
custom-calls, so the kernel lowers to plain HLO ops (see
/opt/xla-example/README.md). On a real TPU the same code compiles with
``interpret=False``.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Block size in elements (§Perf, iteration 2). The kernel is element-wise
# and HBM-bound, so larger blocks amortize grid/dispatch overhead: 32768
# f32 = 128 KiB per operand; with 3 inputs + 2 outputs resident that is
# 640 KiB of VMEM per grid step — double-buffered, well under the ~16 MiB
# VMEM budget. (Iteration 1 used 4096 = 80 KiB/step: correct but 8× more
# grid steps than needed for a pure-bandwidth kernel.)
BLOCK = 32768


def _grid(n):
    return (n + BLOCK - 1) // BLOCK


def _scalar_spec():
    # The scalar bundle is a small (k,) f32 vector mapped whole to every
    # grid step (index_map pins block 0).
    return pl.BlockSpec((8,), lambda i: (0,))


def _vec_spec():
    return pl.BlockSpec((BLOCK,), lambda i: (i,))


def _weights(scal_ref):
    """Recover (wa, wb) from the scalar bundle: s[0]=eta, s[1]=dt."""
    c = jnp.exp(-2.0 * scal_ref[0] * scal_ref[1])
    return 0.5 * (1.0 + c), 0.5 * (1.0 - c)


def _mix_grad_kernel(scal_ref, x_ref, xt_ref, g_ref, ox_ref, oxt_ref):
    """out = mixing flow fused with the gradient step on both rows.

    scal layout: [eta, dt, gamma, 0, 0, 0, 0, 0]
    """
    wa, wb = _weights(scal_ref)
    gamma = scal_ref[2]
    x = x_ref[...]
    xt = xt_ref[...]
    step = gamma * g_ref[...]
    ox_ref[...] = wa * x + wb * xt - step
    oxt_ref[...] = wb * x + wa * xt - step


def _mix_comm_kernel(scal_ref, x_ref, xt_ref, xp_ref, ox_ref, oxt_ref):
    """out = mixing flow fused with the p2p averaging update.

    scal layout: [eta, dt, alpha, alpha_tilde, 0, 0, 0, 0]
    """
    wa, wb = _weights(scal_ref)
    alpha = scal_ref[2]
    alpha_tilde = scal_ref[3]
    x = x_ref[...]
    xt = xt_ref[...]
    mx = wa * x + wb * xt
    mxt = wb * x + wa * xt
    m = mx - xp_ref[...]
    ox_ref[...] = mx - alpha * m
    oxt_ref[...] = mxt - alpha_tilde * m


def _pack_scalars(*vals):
    s = jnp.zeros((8,), jnp.float32)
    for i, v in enumerate(vals):
        s = s.at[i].set(v.astype(jnp.float32) if hasattr(v, "astype") else v)
    return s


@functools.partial(jax.named_call, name="acid_mix_grad")
def mix_grad(x, xt, g, eta, dt, gamma):
    """Fused momentum mixing + gradient step over a flat f32 vector.

    Matches ``ref.mix_grad`` to f32 precision for any (eta >= 0, dt >= 0).
    """
    n = x.shape[0]
    scal = _pack_scalars(eta, dt, gamma)
    return pl.pallas_call(
        _mix_grad_kernel,
        grid=(_grid(n),),
        in_specs=[_scalar_spec(), _vec_spec(), _vec_spec(), _vec_spec()],
        out_specs=[_vec_spec(), _vec_spec()],
        out_shape=[
            jax.ShapeDtypeStruct((n,), x.dtype),
            jax.ShapeDtypeStruct((n,), x.dtype),
        ],
        interpret=True,
    )(scal, x, xt, g)


@functools.partial(jax.named_call, name="acid_mix_comm")
def mix_comm(x, xt, x_peer, eta, dt, alpha, alpha_tilde):
    """Fused momentum mixing + p2p averaging over a flat f32 vector.

    ``x_peer`` must already be mixed to the event time (the engine's
    contract; see ref.mix_comm).
    """
    n = x.shape[0]
    scal = _pack_scalars(eta, dt, alpha, alpha_tilde)
    return pl.pallas_call(
        _mix_comm_kernel,
        grid=(_grid(n),),
        in_specs=[_scalar_spec(), _vec_spec(), _vec_spec(), _vec_spec()],
        out_specs=[_vec_spec(), _vec_spec()],
        out_shape=[
            jax.ShapeDtypeStruct((n,), x.dtype),
            jax.ShapeDtypeStruct((n,), x.dtype),
        ],
        interpret=True,
    )(scal, x, xt, x_peer)
