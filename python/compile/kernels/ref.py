"""Pure-jnp oracles for the A2CiD2 kernels.

These are the ground truth the Pallas kernels (``acid_mix.py``) are
verified against in ``python/tests/test_kernel.py`` (pytest + hypothesis),
and they mirror the closed-form math of the paper:

between two events, the (x, x~) pair of a worker follows the mixing ODE
``d(x, x~)/dt = [[-eta, eta], [eta, -eta]] (x, x~)`` whose flow is

    exp(dt * A) = [[(1+c)/2, (1-c)/2],
                   [(1-c)/2, (1+c)/2]],   c = exp(-2 * eta * dt).

A gradient spike then applies ``-gamma * g`` to BOTH rows (Eq. 4), and a
communication spike applies ``-alpha * m`` to x and ``-alpha_tilde * m``
to x~ with ``m = x_mixed - x_peer`` (Algorithm 1, lines 15-19).
"""

import jax.numpy as jnp


def mix_weights(eta, dt):
    """Mixing weights (wa, wb) of exp(dt * [[-eta, eta], [eta, -eta]])."""
    c = jnp.exp(-2.0 * eta * dt)
    return 0.5 * (1.0 + c), 0.5 * (1.0 - c)


def mix(x, xt, eta, dt):
    """Apply the continuous momentum flow for elapsed time dt."""
    wa, wb = mix_weights(eta, dt)
    return wa * x + wb * xt, wb * x + wa * xt


def mix_grad(x, xt, g, eta, dt, gamma):
    """Momentum flow then gradient step on both rows (SDE Eq. 4)."""
    mx, mxt = mix(x, xt, eta, dt)
    return mx - gamma * g, mxt - gamma * g


def mix_comm(x, xt, x_peer, eta, dt, alpha, alpha_tilde):
    """Momentum flow then the p2p averaging update.

    ``x_peer`` must already be mixed to the event time (both endpoints mix
    first, then exchange) — the same contract as the Rust engines.
    """
    mx, mxt = mix(x, xt, eta, dt)
    m = mx - x_peer
    return mx - alpha * m, mxt - alpha_tilde * m
